//! The user-facing OPS-style API: declaration calls, the parallel-loop
//! construct, and the data-returning calls that trigger chain execution.

use super::block::{Block, BlockId};
use super::dataset::{DataStore, Dataset, DatasetId};
use super::kernel::Kernel;
use super::kir::KernelIr;
use super::parloop::{Arg, LoopInst, Range3};
use std::sync::Arc;
use super::reduction::{RedOp, Reduction, ReductionId};
use super::stencil::{Stencil, StencilId};
use crate::exec::{Engine, Executor, Metrics, NativeExecutor, World};
use crate::lazy::LoopQueue;

/// The library context: owns all data, the lazy queue, the executor and
/// the memory engine. The analogue of an OPS instance.
///
/// Deprecated: `OpsContext` is the legacy *eager* surface — it re-runs
/// the chain dependency/footprint analysis at every flush, exactly what
/// the Program/Session split amortises away. It is kept as a thin,
/// fully-working shim so out-of-tree snippets keep compiling; new code
/// should declare through [`crate::program::ProgramBuilder`], freeze a
/// [`crate::program::Program`] and execute through
/// [`crate::program::Session`] (see `rust/README.md` for the migration
/// table).
#[deprecated(
    since = "0.3.0",
    note = "use ProgramBuilder/Session (crate::program): OpsContext re-analyses every \
            chain at every flush instead of reusing the frozen Program analysis"
)]
pub struct OpsContext {
    blocks: Vec<Block>,
    datasets: Vec<Dataset>,
    stencils: Vec<Stencil>,
    reds: Vec<Reduction>,
    store: DataStore,
    queue: LoopQueue,
    engine: Box<dyn Engine>,
    exec: Box<dyn Executor>,
    metrics: Metrics,
    cyclic_phase: bool,
    oom: bool,
    /// Uniform modelled element size for newly declared datasets: 8 bytes
    /// × the problem-scale factor (see DESIGN.md §5 — numerics run small,
    /// byte accounting models the paper's sizes).
    elem_bytes: u64,
}

#[allow(deprecated)]
impl OpsContext {
    /// Create a context with an explicit engine; uses the native executor.
    pub fn new(engine: Box<dyn Engine>) -> Self {
        OpsContext {
            blocks: vec![],
            datasets: vec![],
            stencils: vec![],
            reds: vec![],
            store: DataStore::new(),
            queue: LoopQueue::new(),
            engine,
            exec: Box::new(NativeExecutor::new()),
            metrics: Metrics::new(),
            cyclic_phase: false,
            oom: false,
            elem_bytes: 8,
        }
    }

    /// Swap in a different numeric executor (e.g. the PJRT backend).
    pub fn set_executor(&mut self, exec: Box<dyn Executor>) {
        self.exec = exec;
    }

    /// Set the modelled bytes-per-element scale for subsequently declared
    /// datasets (`8 * scale`): lets a small actual grid model a paper-
    /// sized problem byte-for-byte in the simulator.
    pub fn set_model_elem_bytes(&mut self, elem_bytes: u64) {
        self.elem_bytes = elem_bytes;
    }

    // ---- declarations ----------------------------------------------------

    pub fn decl_block(&mut self, name: &str, size: [usize; 3]) -> BlockId {
        let id = BlockId(self.blocks.len() as u32);
        let dims = if size[2] > 1 { 3 } else { 2 };
        self.blocks.push(Block {
            id,
            name: name.to_string(),
            size,
            dims,
        });
        id
    }

    /// Declare a dataset on `block` with interior `size` and halo depths.
    pub fn decl_dat(
        &mut self,
        block: BlockId,
        name: &str,
        size: [usize; 3],
        halo_lo: [i32; 3],
        halo_hi: [i32; 3],
    ) -> DatasetId {
        let id = DatasetId(self.datasets.len() as u32);
        let ds = Dataset {
            id,
            block,
            name: name.to_string(),
            size,
            halo_lo,
            halo_hi,
            elem_bytes: self.elem_bytes,
        };
        self.store.alloc(&ds);
        self.datasets.push(ds);
        id
    }

    pub fn decl_stencil(&mut self, name: &str, points: Vec<[i32; 3]>) -> StencilId {
        let id = StencilId(self.stencils.len() as u32);
        self.stencils.push(Stencil {
            id,
            name: name.to_string(),
            points,
        });
        id
    }

    pub fn decl_reduction(&mut self, name: &str, op: RedOp) -> ReductionId {
        let id = ReductionId(self.reds.len() as u32);
        self.reds.push(Reduction::new(id, name, op));
        id
    }

    // ---- the parallel loop -----------------------------------------------

    /// Enqueue a parallel loop (§3, Fig. 1). Execution is deferred until a
    /// data-returning API call.
    ///
    /// Panics if an argument references an undeclared handle, or if a
    /// dataset is written through one argument while also appearing in
    /// another (OPS's no-aliasing contract — required for tiling to be a
    /// pure reordering).
    pub fn par_loop(
        &mut self,
        name: &str,
        block: BlockId,
        range: Range3,
        kernel: Kernel,
        args: Vec<Arg>,
    ) {
        self.par_loop_eff(name, block, range, kernel, args, 1.0)
    }

    /// [`Self::par_loop`] with an explicit bandwidth-efficiency factor
    /// (relative to the app baseline; models latency-/compute-bound
    /// kernels such as OpenSBLI's dominant RHS evaluation).
    pub fn par_loop_eff(
        &mut self,
        name: &str,
        block: BlockId,
        range: Range3,
        kernel: Kernel,
        args: Vec<Arg>,
        bw_efficiency: f64,
    ) {
        // Validate handles + aliasing (the one shared contract — the
        // frozen recorder and the Session queue use the same helper).
        crate::program::builder::validate_loop("ops", name, &args, &self.datasets, &self.stencils);
        let has_red = args.iter().any(|a| matches!(a, Arg::GblRed { .. }));

        self.queue.push(LoopInst {
            name: name.to_string(),
            block,
            range,
            args,
            kernel,
            kernel_ir: None,
            seq: 0,
            bw_efficiency,
        });

        // A reduction returns data to user space only when queried, but it
        // still ends the analysable chain in OPS once queried; we keep the
        // loop queued and flush on the query. (No action needed here; the
        // flag is informative.)
        let _ = has_red;
    }

    /// [`Self::par_loop_eff`] from a declarative [`KernelIr`] body: the
    /// closure is derived from the IR, and the IR rides along on the
    /// queued loop for IR-specialising executors.
    pub fn par_loop_ir(
        &mut self,
        name: &str,
        block: BlockId,
        range: Range3,
        ir: KernelIr,
        args: Vec<Arg>,
        bw_efficiency: f64,
    ) {
        crate::program::builder::validate_loop("ops", name, &args, &self.datasets, &self.stencils);
        let ir = Arc::new(ir);
        self.queue.push(LoopInst {
            name: name.to_string(),
            block,
            range,
            args,
            kernel: ir.to_kernel(),
            kernel_ir: Some(ir),
            seq: 0,
            bw_efficiency,
        });
    }

    // ---- trigger points (return data to user space) ------------------------

    /// Execute everything queued. Called internally by the data-returning
    /// APIs; public for drivers that want chain boundaries at timestep
    /// granularity.
    pub fn flush(&mut self) {
        let chain = self.queue.take_chain();
        if chain.is_empty() {
            return;
        }
        // The eager path hands the engine no cached analysis, so the
        // chain is re-analysed on every flush — the cost the
        // Program/Session split amortises away.
        self.metrics.analysis_builds += 1;
        let problem = crate::tiling::plan::chain_bytes(&chain, &self.datasets);
        if !self.engine.fits(problem) {
            self.oom = true;
        }
        let mut world = World {
            datasets: &self.datasets,
            stencils: &self.stencils,
            store: &mut self.store,
            reds: &mut self.reds,
            metrics: &mut self.metrics,
            exec: self.exec.as_mut(),
        };
        self.engine.run_chain(&chain, &mut world, self.cyclic_phase);
    }

    /// Get a reduction result — flushes the queue (§3's canonical trigger
    /// point) and resets the handle for reuse.
    pub fn reduction_result(&mut self, id: ReductionId) -> f64 {
        self.flush();
        let r = &mut self.reds[id.0 as usize];
        let v = r.value;
        r.reset();
        v
    }

    /// Fetch a copy of a dataset's full padded buffer — flushes the queue.
    pub fn fetch(&mut self, id: DatasetId) -> Vec<f64> {
        self.flush();
        self.store.buf(id).to_vec()
    }

    /// Read a single value — flushes the queue.
    pub fn value_at(&mut self, id: DatasetId, idx: [isize; 3]) -> f64 {
        self.flush();
        let off = self.datasets[id.0 as usize].offset(idx) as usize;
        self.store.buf(id)[off]
    }

    /// Periodic halo exchange along `dim` to depth `depth` — the OPS/MPI
    /// exchange path, which happens **between** loop chains (this flushes
    /// first). Modelled cost: one exchange latency + bytes at exchange
    /// bandwidth, charged to halo time. OpenSBLI's periodic boundaries use
    /// this with deep halos so chains can tile across multiple timesteps
    /// (redundant halo-deep computation, as OPS does under MPI+tiling).
    pub fn exchange_periodic(&mut self, id: DatasetId, dim: usize, depth: usize) {
        self.flush();
        let ds = self.datasets[id.0 as usize].clone();
        let t = periodic_exchange(&ds, &mut self.store, dim, depth);
        self.metrics.halo_time_s += t;
        self.metrics.halo_exchanges += 1;
        self.metrics.elapsed_s += t;
    }

    // ---- application signals ----------------------------------------------

    /// §4.1: the application declares that the regular cyclic execution
    /// pattern has begun (enables the unsafe skip-download-of-temporaries
    /// optimisation on GPU engines).
    pub fn set_cyclic_phase(&mut self, on: bool) {
        self.cyclic_phase = on;
    }

    // ---- introspection ------------------------------------------------------

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    /// Reset metrics (e.g. after a warm-up phase, as the paper's timed
    /// region excludes initialisation).
    pub fn reset_metrics(&mut self) {
        self.metrics = Metrics::new();
    }

    /// Did any executed chain exceed the engine's memory (the paper's
    /// flat-MCDRAM/GPU-baseline segfault condition)?
    pub fn oom(&self) -> bool {
        self.oom
    }

    /// Modelled total bytes of all declared datasets.
    pub fn problem_bytes(&self) -> u64 {
        self.datasets.iter().map(|d| d.bytes()).sum()
    }

    pub fn engine_description(&self) -> String {
        self.engine.describe()
    }

    pub fn dataset(&self, id: DatasetId) -> &Dataset {
        &self.datasets[id.0 as usize]
    }

    pub fn datasets(&self) -> &[Dataset] {
        &self.datasets
    }

    pub fn stencils(&self) -> &[Stencil] {
        &self.stencils
    }

    pub fn queued_loops(&self) -> usize {
        self.queue.len()
    }

    /// Direct (untimed) access for initialisation from host files etc.
    pub fn store_mut(&mut self) -> &mut DataStore {
        &mut self.store
    }

    pub fn store(&self) -> &DataStore {
        &self.store
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::super::access::Access;
    use super::*;
    use crate::memory::PlainEngine;
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::shapes;

    fn ctx() -> OpsContext {
        OpsContext::new(Box::new(PlainEngine {
            bw_gbs: 100.0,
            mem_limit: None,
            launch_s: 0.0,
            halo: None,
            label: "test".into(),
        }))
    }

    #[test]
    fn loops_are_lazy_until_trigger() {
        let mut c = ctx();
        let b = c.decl_block("g", [8, 8, 1]);
        let d = c.decl_dat(b, "d", [8, 8, 1], [0; 3], [0; 3]);
        let s = c.decl_stencil("pt", shapes::point());
        c.par_loop(
            "set",
            b,
            [(0, 8), (0, 8), (0, 1)],
            kernel(|c| c.w(0, 0, 0, 7.0)),
            vec![Arg::dat(d, s, Access::Write)],
        );
        assert_eq!(c.queued_loops(), 1);
        assert_eq!(c.metrics().loop_bytes, 0, "nothing ran yet");
        let v = c.value_at(d, [3, 3, 0]);
        assert_eq!(v, 7.0);
        assert_eq!(c.queued_loops(), 0);
        assert!(c.metrics().loop_bytes > 0);
    }

    #[test]
    fn reduction_triggers_and_resets() {
        let mut c = ctx();
        let b = c.decl_block("g", [4, 4, 1]);
        let d = c.decl_dat(b, "d", [4, 4, 1], [0; 3], [0; 3]);
        let s = c.decl_stencil("pt", shapes::point());
        let r = c.decl_reduction("sum", RedOp::Sum);
        c.par_loop(
            "ones",
            b,
            [(0, 4), (0, 4), (0, 1)],
            kernel(|c| c.w(0, 0, 0, 1.0)),
            vec![Arg::dat(d, s, Access::Write)],
        );
        c.par_loop(
            "sum",
            b,
            [(0, 4), (0, 4), (0, 1)],
            kernel(|c| {
                let v = c.r(0, 0, 0);
                c.red_sum(0, v);
            }),
            vec![
                Arg::dat(d, s, Access::Read),
                Arg::GblRed {
                    red: r,
                    op: RedOp::Sum,
                },
            ],
        );
        assert_eq!(c.reduction_result(r), 16.0);
        // handle reset: querying again (no new loops) gives identity.
        assert_eq!(c.reduction_result(r), 0.0);
    }

    #[test]
    #[should_panic(expected = "aliased")]
    fn aliased_write_is_rejected() {
        let mut c = ctx();
        let b = c.decl_block("g", [4, 4, 1]);
        let d = c.decl_dat(b, "d", [4, 4, 1], [0; 3], [0; 3]);
        let s = c.decl_stencil("pt", shapes::point());
        c.par_loop(
            "bad",
            b,
            [(0, 4), (0, 4), (0, 1)],
            kernel(|_| {}),
            vec![
                Arg::dat(d, s, Access::Write),
                Arg::dat(d, s, Access::Read),
            ],
        );
    }

    #[test]
    fn oom_flag_set_when_engine_refuses() {
        let mut c = OpsContext::new(Box::new(PlainEngine {
            bw_gbs: 100.0,
            mem_limit: Some(16),
            launch_s: 0.0,
            halo: None,
            label: "tiny".into(),
        }));
        let b = c.decl_block("g", [8, 8, 1]);
        let d = c.decl_dat(b, "d", [8, 8, 1], [0; 3], [0; 3]);
        let s = c.decl_stencil("pt", shapes::point());
        c.par_loop(
            "w",
            b,
            [(0, 8), (0, 8), (0, 1)],
            kernel(|c| c.w(0, 0, 0, 1.0)),
            vec![Arg::dat(d, s, Access::Write)],
        );
        c.flush();
        assert!(c.oom());
    }

    #[test]
    fn model_elem_bytes_scales_problem() {
        let mut c = ctx();
        let b = c.decl_block("g", [8, 8, 1]);
        c.set_model_elem_bytes(8 * 1024);
        let d = c.decl_dat(b, "d", [8, 8, 1], [0; 3], [0; 3]);
        assert_eq!(c.dataset(d).elem_bytes, 8 * 1024);
        assert_eq!(c.problem_bytes(), 64 * 8 * 1024);
    }
}

#[allow(deprecated)]
impl OpsContext {
    /// Drain the queue without executing — diagnostics/planning tools.
    pub fn take_chain_for_debug(&mut self) -> Vec<LoopInst> {
        self.queue.take_chain()
    }
}

// ---------------------------------------------------------------------------
// Shared periodic-exchange data movement (used by both OpsContext and
// crate::program::Session).

/// Apply the periodic copies of an `exchange_periodic` call along `dim`
/// to depth `depth` and return the modelled exchange time in seconds
/// (one exchange latency + bytes at exchange bandwidth). Metrics are the
/// caller's responsibility.
pub(crate) fn periodic_exchange(
    ds: &Dataset,
    store: &mut DataStore,
    dim: usize,
    depth: usize,
) -> f64 {
    let n = ds.size[dim] as isize;
    assert!(
        depth as isize <= n,
        "periodic exchange depth {depth} exceeds extent {n} of {}",
        ds.name
    );
    // Copy plane(-k) = plane(n-k) and plane(n-1+k) = plane(k-1).
    for k in 1..=depth as isize {
        copy_plane(ds, store, dim, n - k, -k);
        copy_plane(ds, store, dim, k - 1, n - 1 + k);
    }
    // Time model: one exchange of 2*depth representative planes (see
    // Dataset::repr_plane_bytes on the tall-grid correction).
    let bytes = 2 * depth as u64 * ds.repr_plane_bytes();
    8e-6 + bytes as f64 / 12e9
}

/// Copy one whole plane of `ds` along `dim` (`src` → `dst` logical
/// indices), spanning the full padded extent of the other dims.
fn copy_plane(ds: &Dataset, store: &mut DataStore, dim: usize, src: isize, dst: isize) {
    let lo = [
        -(ds.halo_lo[0] as isize),
        -(ds.halo_lo[1] as isize),
        -(ds.halo_lo[2] as isize),
    ];
    let hi = [
        ds.size[0] as isize + ds.halo_hi[0] as isize,
        ds.size[1] as isize + ds.halo_hi[1] as isize,
        ds.size[2] as isize + ds.halo_hi[2] as isize,
    ];
    let buf = store.buf_mut(ds.id);
    // Pointwise copy over the plane; src and dst planes are disjoint.
    let (d0, d1) = match dim {
        0 => (1, 2),
        1 => (0, 2),
        2 => (0, 1),
        _ => unreachable!(),
    };
    for b in lo[d1]..hi[d1] {
        for a in lo[d0]..hi[d0] {
            let mut si = [0isize; 3];
            si[dim] = src;
            si[d0] = a;
            si[d1] = b;
            let mut di = si;
            di[dim] = dst;
            let so = ds.offset(si) as usize;
            let do_ = ds.offset(di) as usize;
            buf[do_] = buf[so];
        }
    }
}

// ---------------------------------------------------------------------------
// Capability-trait implementations: the legacy shim speaks the same
// Declare/Record/Drive surface the Program/Session API does, so every
// application runs unchanged on either.

#[allow(deprecated)]
impl crate::ops::surface::Declare for OpsContext {
    fn set_model_elem_bytes(&mut self, elem_bytes: u64) {
        OpsContext::set_model_elem_bytes(self, elem_bytes)
    }

    fn decl_block(&mut self, name: &str, size: [usize; 3]) -> BlockId {
        OpsContext::decl_block(self, name, size)
    }

    fn decl_dat(
        &mut self,
        block: BlockId,
        name: &str,
        size: [usize; 3],
        halo_lo: [i32; 3],
        halo_hi: [i32; 3],
    ) -> DatasetId {
        OpsContext::decl_dat(self, block, name, size, halo_lo, halo_hi)
    }

    fn decl_stencil(&mut self, name: &str, points: Vec<[i32; 3]>) -> StencilId {
        OpsContext::decl_stencil(self, name, points)
    }

    fn decl_reduction(&mut self, name: &str, op: RedOp) -> ReductionId {
        OpsContext::decl_reduction(self, name, op)
    }
}

#[allow(deprecated)]
impl crate::ops::surface::Record for OpsContext {
    fn par_loop_eff(
        &mut self,
        name: &str,
        block: BlockId,
        range: Range3,
        kernel: Kernel,
        args: Vec<Arg>,
        bw_efficiency: f64,
    ) {
        OpsContext::par_loop_eff(self, name, block, range, kernel, args, bw_efficiency)
    }

    fn par_loop_ir(
        &mut self,
        name: &str,
        block: BlockId,
        range: Range3,
        ir: KernelIr,
        args: Vec<Arg>,
        bw_efficiency: f64,
    ) {
        OpsContext::par_loop_ir(self, name, block, range, ir, args, bw_efficiency)
    }
}

#[allow(deprecated)]
impl crate::ops::surface::Drive for OpsContext {
    fn flush(&mut self) {
        OpsContext::flush(self)
    }

    fn reduction_result(&mut self, id: ReductionId) -> f64 {
        OpsContext::reduction_result(self, id)
    }

    fn fetch(&mut self, id: DatasetId) -> Vec<f64> {
        OpsContext::fetch(self, id)
    }

    fn value_at(&mut self, id: DatasetId, idx: [isize; 3]) -> f64 {
        OpsContext::value_at(self, id, idx)
    }

    fn exchange_periodic(&mut self, id: DatasetId, dim: usize, depth: usize) {
        OpsContext::exchange_periodic(self, id, dim, depth)
    }

    fn set_cyclic_phase(&mut self, on: bool) {
        OpsContext::set_cyclic_phase(self, on)
    }

    fn reset_metrics(&mut self) {
        OpsContext::reset_metrics(self)
    }
}
