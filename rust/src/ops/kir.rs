//! Declarative kernel IR — the vectorisable subset of stencil kernels.
//!
//! A [`KernelIr`] describes a kernel body as a short list of statements
//! over an expression tree: reads of dataset arguments at constant
//! stencil offsets, literals, loop-invariant globals, the iteration
//! index, and previously-bound locals. Kernels recorded through
//! [`Record::par_loop_ir`](crate::ops::Record::par_loop_ir) carry the IR
//! on [`LoopInst`](crate::ops::LoopInst) *alongside* a closure derived
//! from it with [`KernelIr::to_kernel`], so every executor still works:
//! the [`NativeExecutor`](crate::exec::NativeExecutor) interprets the
//! closure point-by-point, while the
//! [`VectorExecutor`](crate::exec::VectorExecutor) compiles the IR once
//! into a row program of slice-based x-inner loops the autovectoriser
//! can chew on.
//!
//! Bit-exactness is by construction: both paths evaluate the *same*
//! expression tree with the same scalar operators ([`UnOp::apply`],
//! [`BinOp::apply`]) — the vector path merely changes the loop nest from
//! point-major to statement-major, which is legal because compilation
//! rejects (falls back on) any kernel whose reads of a written argument
//! are not at the centre point.
//!
//! The IR has a stable text form (`Display` + [`KernelIr::parse`]) used
//! by the round-trip tests and handy for debugging:
//!
//! ```text
//! let (sub (add (add (add (read 0 -1 0 0) (read 0 1 0 0)) (read 0 0 -1 0))
//!     (read 0 0 1 0)) (mul (lit 4.0) (read 0 0 0 0)))
//! store 2 (mul (loc 1) (loc 0))
//! reduce 0 sum (read 0 0 0 0)
//! ```

use super::kernel::{kernel, Ctx, Kernel};
use super::reduction::RedOp;
use std::fmt;
use std::sync::{Arc, OnceLock};

/// Upper bound on `let`-bound locals per kernel (the interpreter keeps
/// them in a fixed stack array; the row compiler allocates one row
/// buffer per local).
pub const MAX_LOCALS: usize = 64;

/// Unary scalar operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnOp {
    Neg,
    Abs,
    Sqrt,
}

impl UnOp {
    /// The single scalar semantics both executors share.
    #[inline(always)]
    pub fn apply(self, v: f64) -> f64 {
        match self {
            UnOp::Neg => -v,
            UnOp::Abs => v.abs(),
            UnOp::Sqrt => v.sqrt(),
        }
    }

    fn name(self) -> &'static str {
        match self {
            UnOp::Neg => "neg",
            UnOp::Abs => "abs",
            UnOp::Sqrt => "sqrt",
        }
    }
}

/// Binary scalar operators. Comparisons yield `1.0`/`0.0` (select masks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
    Gt,
    Ge,
    Lt,
    Le,
}

impl BinOp {
    /// The single scalar semantics both executors share.
    #[inline(always)]
    pub fn apply(self, a: f64, b: f64) -> f64 {
        match self {
            BinOp::Add => a + b,
            BinOp::Sub => a - b,
            BinOp::Mul => a * b,
            BinOp::Div => a / b,
            BinOp::Min => a.min(b),
            BinOp::Max => a.max(b),
            BinOp::Gt => {
                if a > b {
                    1.0
                } else {
                    0.0
                }
            }
            BinOp::Ge => {
                if a >= b {
                    1.0
                } else {
                    0.0
                }
            }
            BinOp::Lt => {
                if a < b {
                    1.0
                } else {
                    0.0
                }
            }
            BinOp::Le => {
                if a <= b {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    fn name(self) -> &'static str {
        match self {
            BinOp::Add => "add",
            BinOp::Sub => "sub",
            BinOp::Mul => "mul",
            BinOp::Div => "div",
            BinOp::Min => "min",
            BinOp::Max => "max",
            BinOp::Gt => "gt",
            BinOp::Ge => "ge",
            BinOp::Lt => "lt",
            BinOp::Le => "le",
        }
    }
}

/// A pure scalar expression over the kernel's per-point environment.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    /// Dataset argument `arg` at constant stencil offset `off`.
    Read { arg: usize, off: [i32; 3] },
    /// Literal constant (captured at record time, like closure captures).
    Lit(f64),
    /// Loop-invariant global: flat index into the concatenated
    /// [`Arg::GblConst`](crate::ops::Arg::GblConst) values.
    Gbl(usize),
    /// Iteration index component (0 = x, 1 = y, 2 = z) as `f64`.
    Idx(usize),
    /// A previously `let`-bound statement value.
    Local(usize),
    Unary(UnOp, Box<Expr>),
    Binary(BinOp, Box<Expr>, Box<Expr>),
    /// `if cond != 0.0 { then } else { els }`. Both branches are pure, so
    /// the vector path may evaluate both and blend.
    Select {
        cond: Box<Expr>,
        then: Box<Expr>,
        els: Box<Expr>,
    },
}

/// Read of dataset argument `arg` at stencil offset `off`.
pub fn read(arg: usize, off: [i32; 3]) -> Expr {
    Expr::Read { arg, off }
}

/// Literal constant.
pub fn lit(v: f64) -> Expr {
    Expr::Lit(v)
}

/// Loop-invariant global constant (flat `Ctx::gbl` index).
pub fn gbl(i: usize) -> Expr {
    Expr::Gbl(i)
}

/// Iteration index component as `f64`.
pub fn idx(d: usize) -> Expr {
    Expr::Idx(d)
}

impl From<f64> for Expr {
    fn from(v: f64) -> Expr {
        Expr::Lit(v)
    }
}

impl Expr {
    fn bin(op: BinOp, a: Expr, b: Expr) -> Expr {
        Expr::Binary(op, Box::new(a), Box::new(b))
    }

    pub fn min(self, o: impl Into<Expr>) -> Expr {
        Expr::bin(BinOp::Min, self, o.into())
    }

    pub fn max(self, o: impl Into<Expr>) -> Expr {
        Expr::bin(BinOp::Max, self, o.into())
    }

    pub fn gt(self, o: impl Into<Expr>) -> Expr {
        Expr::bin(BinOp::Gt, self, o.into())
    }

    pub fn ge(self, o: impl Into<Expr>) -> Expr {
        Expr::bin(BinOp::Ge, self, o.into())
    }

    pub fn lt(self, o: impl Into<Expr>) -> Expr {
        Expr::bin(BinOp::Lt, self, o.into())
    }

    pub fn le(self, o: impl Into<Expr>) -> Expr {
        Expr::bin(BinOp::Le, self, o.into())
    }

    pub fn abs(self) -> Expr {
        Expr::Unary(UnOp::Abs, Box::new(self))
    }

    pub fn sqrt(self) -> Expr {
        Expr::Unary(UnOp::Sqrt, Box::new(self))
    }

    /// `if self != 0.0 { then } else { els }`.
    pub fn select(self, then: impl Into<Expr>, els: impl Into<Expr>) -> Expr {
        Expr::Select {
            cond: Box::new(self),
            then: Box::new(then.into()),
            els: Box::new(els.into()),
        }
    }
}

macro_rules! impl_expr_bin {
    ($tr:ident, $meth:ident, $op:expr) => {
        impl std::ops::$tr for Expr {
            type Output = Expr;
            fn $meth(self, rhs: Expr) -> Expr {
                Expr::bin($op, self, rhs)
            }
        }
        impl std::ops::$tr<f64> for Expr {
            type Output = Expr;
            fn $meth(self, rhs: f64) -> Expr {
                Expr::bin($op, self, Expr::Lit(rhs))
            }
        }
        impl std::ops::$tr<Expr> for f64 {
            type Output = Expr;
            fn $meth(self, rhs: Expr) -> Expr {
                Expr::bin($op, Expr::Lit(self), rhs)
            }
        }
    };
}

impl_expr_bin!(Add, add, BinOp::Add);
impl_expr_bin!(Sub, sub, BinOp::Sub);
impl_expr_bin!(Mul, mul, BinOp::Mul);
impl_expr_bin!(Div, div, BinOp::Div);

impl std::ops::Neg for Expr {
    type Output = Expr;
    fn neg(self) -> Expr {
        Expr::Unary(UnOp::Neg, Box::new(self))
    }
}

/// One kernel statement, executed in order at every iteration point.
#[derive(Clone, Debug, PartialEq)]
pub enum Stmt {
    /// Bind the next local (locals number 0, 1, … in statement order).
    Let(Expr),
    /// Store to dataset argument `arg` at the centre point `(0,0,0)`.
    Store { arg: usize, expr: Expr },
    /// Accumulate into reduction slot `slot` with `Ctx::red_*` semantics.
    Reduce { slot: usize, op: RedOp, expr: Expr },
}

/// A declarative kernel body: statements over [`Expr`] trees, plus a
/// lazily-compiled row program ([`VectorExecutor`] fast path).
///
/// [`VectorExecutor`]: crate::exec::VectorExecutor
#[derive(Debug)]
pub struct KernelIr {
    pub stmts: Vec<Stmt>,
    plan: OnceLock<Option<RowPlan>>,
}

impl Clone for KernelIr {
    fn clone(&self) -> Self {
        KernelIr::new(self.stmts.clone())
    }
}

impl PartialEq for KernelIr {
    fn eq(&self, other: &Self) -> bool {
        self.stmts == other.stmts
    }
}

impl KernelIr {
    pub fn new(stmts: Vec<Stmt>) -> Self {
        KernelIr {
            stmts,
            plan: OnceLock::new(),
        }
    }

    /// The compiled row program, or `None` if this kernel is outside the
    /// vectorisable subset (the executor then falls back to the closure).
    pub(crate) fn plan(&self) -> Option<&RowPlan> {
        self.plan.get_or_init(|| compile(self)).as_ref()
    }

    /// Does this kernel compile to the vector fast path?
    pub fn is_vectorizable(&self) -> bool {
        self.plan().is_some()
    }

    /// Derive the per-point closure: an interpreter over the public
    /// [`Ctx`] API. Loops recorded via `par_loop_ir` carry this closure,
    /// so the native path and the vector path execute the *same* tree.
    pub fn to_kernel(self: &Arc<Self>) -> Kernel {
        let ir = Arc::clone(self);
        kernel(move |c| ir.apply(c))
    }

    /// Run the kernel body once at the current iteration point.
    pub fn apply(&self, c: &mut Ctx) {
        let mut locals = [0.0f64; MAX_LOCALS];
        let mut n = 0usize;
        for s in &self.stmts {
            match s {
                Stmt::Let(e) => {
                    locals[n] = eval(e, c, &locals);
                    n += 1;
                }
                Stmt::Store { arg, expr } => {
                    let v = eval(expr, c, &locals);
                    c.w3(*arg, 0, 0, 0, v);
                }
                Stmt::Reduce { slot, op, expr } => {
                    let v = eval(expr, c, &locals);
                    match op {
                        RedOp::Sum => c.red_sum(*slot, v),
                        RedOp::Min => c.red_min(*slot, v),
                        RedOp::Max => c.red_max(*slot, v),
                    }
                }
            }
        }
    }
}

fn eval(e: &Expr, c: &Ctx, locals: &[f64]) -> f64 {
    match e {
        Expr::Read { arg, off } => {
            c.r3(*arg, off[0] as isize, off[1] as isize, off[2] as isize)
        }
        Expr::Lit(v) => *v,
        Expr::Gbl(i) => c.gbl(*i),
        Expr::Idx(d) => c.idx()[*d] as f64,
        Expr::Local(i) => locals[*i],
        Expr::Unary(op, a) => op.apply(eval(a, c, locals)),
        Expr::Binary(op, a, b) => op.apply(eval(a, c, locals), eval(b, c, locals)),
        Expr::Select { cond, then, els } => {
            if eval(cond, c, locals) != 0.0 {
                eval(then, c, locals)
            } else {
                eval(els, c, locals)
            }
        }
    }
}

/// Incremental builder with Rust-like `let` ergonomics:
///
/// ```
/// use ops_oc::ops::kir::{lit, read, KirBuilder};
/// let mut k = KirBuilder::new();
/// let l = k.let_(read(0, [-1, 0, 0]) + read(0, [1, 0, 0]) - lit(2.0) * read(0, [0, 0, 0]));
/// k.store(1, l * lit(0.25));
/// let ir = k.build();
/// assert!(ir.is_vectorizable());
/// ```
#[derive(Default)]
pub struct KirBuilder {
    stmts: Vec<Stmt>,
    locals: usize,
}

impl KirBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bind `e` as the next local; returns the [`Expr::Local`] handle.
    pub fn let_(&mut self, e: Expr) -> Expr {
        assert!(self.locals < MAX_LOCALS, "kernel exceeds {MAX_LOCALS} locals");
        self.stmts.push(Stmt::Let(e));
        self.locals += 1;
        Expr::Local(self.locals - 1)
    }

    /// Store `e` to argument `arg` at the centre point.
    pub fn store(&mut self, arg: usize, e: Expr) {
        self.stmts.push(Stmt::Store { arg, expr: e });
    }

    /// Accumulate `e` into reduction slot `slot`.
    pub fn reduce(&mut self, slot: usize, op: RedOp, e: Expr) {
        self.stmts.push(Stmt::Reduce { slot, op, expr: e });
    }

    pub fn build(self) -> KernelIr {
        KernelIr::new(self.stmts)
    }
}

// --------------------------------------------------------------- row plan

/// Destination tag meaning "this statement's output row" in a [`Tape`].
pub(crate) const OUT: u32 = u32::MAX;

/// Row-program operand, resolved per row to a contiguous slice or a
/// scalar splat.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Op {
    /// Dataset argument row at constant offset (x-contiguous slice).
    Read { arg: u32, off: [i32; 3] },
    /// A `let`-bound local's row buffer.
    Local(u32),
    /// A tape-internal register row buffer.
    Reg(u32),
    Lit(f64),
    Gbl(u32),
    /// y / z index splat.
    IdxY,
    IdxZ,
    /// x-index ramp; only ever appears as a [`Step::Mov`] source.
    IotaX,
}

/// One vector instruction over whole rows.
#[derive(Clone, Debug)]
pub(crate) enum Step {
    Mov { dst: u32, a: Op },
    Un { op: UnOp, dst: u32, a: Op },
    Bin { op: BinOp, dst: u32, a: Op, b: Op },
    Sel { dst: u32, c: Op, t: Op, f: Op },
    /// Left-associated add chain of ≥ 3 leaf operands (star stencils).
    Sum { dst: u32, terms: Vec<Op> },
    /// `base + coef·x` with a splat `coef` (update kernels).
    Axpy { dst: u32, base: Op, coef: Op, x: Op },
}

/// The register program for one statement; the last step writes [`OUT`].
#[derive(Clone, Debug)]
pub(crate) struct Tape {
    pub steps: Vec<Step>,
}

/// One compiled statement.
#[derive(Clone, Debug)]
pub(crate) enum PlanStmt {
    Let {
        dst: usize,
        tape: Tape,
    },
    Store {
        arg: usize,
        /// The expression reads the stored argument (at the centre), so
        /// the row must be evaluated into a temp and copied back — never
        /// aliased in place.
        in_place: bool,
        tape: Tape,
    },
    Reduce {
        slot: usize,
        op: RedOp,
        tape: Tape,
    },
}

/// A compiled kernel: statement-major row passes, executed per (y, z) row.
#[derive(Clone, Debug)]
pub(crate) struct RowPlan {
    pub steps: Vec<PlanStmt>,
    pub n_locals: usize,
    pub n_regs: usize,
    /// Dataset argument indices touched are `< n_args`.
    pub n_args: usize,
    /// Required length of the flat global-constant table.
    pub n_gbl: usize,
    /// Required number of reduction slots.
    pub n_red: usize,
    /// Every (arg, offset) access — reads plus centre writes — for the
    /// debug-mode bounds pre-check (the row path bypasses `Ctx::addr`).
    pub accesses: Vec<(usize, [i32; 3])>,
}

fn walk(e: &Expr, f: &mut impl FnMut(&Expr)) {
    f(e);
    match e {
        Expr::Unary(_, a) => walk(a, f),
        Expr::Binary(_, a, b) => {
            walk(a, f);
            walk(b, f);
        }
        Expr::Select { cond, then, els } => {
            walk(cond, f);
            walk(then, f);
            walk(els, f);
        }
        _ => {}
    }
}

fn expr_reads_arg(e: &Expr, arg: usize) -> bool {
    let mut found = false;
    walk(e, &mut |n| {
        if matches!(n, Expr::Read { arg: a, .. } if *a == arg) {
            found = true;
        }
    });
    found
}

fn stmt_expr(s: &Stmt) -> &Expr {
    match s {
        Stmt::Let(e) => e,
        Stmt::Store { expr, .. } => expr,
        Stmt::Reduce { expr, .. } => expr,
    }
}

/// Leaf operands (resolve to a slice or splat without any tape step).
fn leaf_op(e: &Expr) -> Option<Op> {
    match e {
        Expr::Read { arg, off } => Some(Op::Read {
            arg: *arg as u32,
            off: *off,
        }),
        Expr::Local(i) => Some(Op::Local(*i as u32)),
        Expr::Lit(v) => Some(Op::Lit(*v)),
        Expr::Gbl(i) => Some(Op::Gbl(*i as u32)),
        Expr::Idx(1) => Some(Op::IdxY),
        Expr::Idx(2) => Some(Op::IdxZ),
        _ => None,
    }
}

/// Scalar-splat operands (loop-invariant within a row).
fn splat_op(e: &Expr) -> Option<Op> {
    match e {
        Expr::Lit(_) | Expr::Gbl(_) | Expr::Idx(1) | Expr::Idx(2) => leaf_op(e),
        _ => None,
    }
}

/// Collect a left-associated all-leaf add chain into `out`.
fn add_chain(e: &Expr, out: &mut Vec<Op>) -> bool {
    match e {
        Expr::Binary(BinOp::Add, a, b) => {
            if let Some(bo) = leaf_op(b) {
                if add_chain(a, out) {
                    out.push(bo);
                    return true;
                }
            }
            false
        }
        _ => {
            if let Some(o) = leaf_op(e) {
                out.push(o);
                true
            } else {
                false
            }
        }
    }
}

/// Match `base + coef·x` (or `base + x·coef`) with leaf `base`/`x` and a
/// splat `coef`. `coef·x` and `x·coef` are bit-identical, so the fused
/// loop always computes `base + coef·x`.
fn as_axpy(e: &Expr) -> Option<(Op, Op, Op)> {
    if let Expr::Binary(BinOp::Add, base, m) = e {
        let base = leaf_op(base)?;
        if let Expr::Binary(BinOp::Mul, a, b) = &**m {
            if let (Some(coef), Some(x)) = (splat_op(a), leaf_op(b)) {
                return Some((base, coef, x));
            }
            if let (Some(x), Some(coef)) = (leaf_op(a), splat_op(b)) {
                return Some((base, coef, x));
            }
        }
    }
    None
}

/// Register-allocating expression compiler. Destination registers are
/// allocated *before* operand registers are released, so a step's `dst`
/// is never one of its own operands — the row executor relies on this
/// for aliasing-free slice access.
#[derive(Default)]
struct Comp {
    steps: Vec<Step>,
    free: Vec<u32>,
    n_regs: u32,
}

impl Comp {
    fn alloc(&mut self) -> u32 {
        self.free.pop().unwrap_or_else(|| {
            self.n_regs += 1;
            self.n_regs - 1
        })
    }

    fn release(&mut self, op: Op) {
        if let Op::Reg(r) = op {
            self.free.push(r);
        }
    }

    fn operand(&mut self, e: &Expr) -> Op {
        if let Some(o) = leaf_op(e) {
            return o;
        }
        let d = self.alloc();
        self.emit(e, d);
        Op::Reg(d)
    }

    fn emit(&mut self, e: &Expr, dst: u32) {
        let mut terms = Vec::new();
        if add_chain(e, &mut terms) && terms.len() >= 3 {
            self.steps.push(Step::Sum { dst, terms });
            return;
        }
        if let Some((base, coef, x)) = as_axpy(e) {
            self.steps.push(Step::Axpy { dst, base, coef, x });
            return;
        }
        if let Some(a) = leaf_op(e) {
            self.steps.push(Step::Mov { dst, a });
            return;
        }
        match e {
            Expr::Idx(0) => self.steps.push(Step::Mov { dst, a: Op::IotaX }),
            Expr::Unary(op, a) => {
                let ao = self.operand(a);
                self.steps.push(Step::Un { op: *op, dst, a: ao });
                self.release(ao);
            }
            Expr::Binary(op, a, b) => {
                let ao = self.operand(a);
                let bo = self.operand(b);
                self.steps.push(Step::Bin {
                    op: *op,
                    dst,
                    a: ao,
                    b: bo,
                });
                self.release(ao);
                self.release(bo);
            }
            Expr::Select { cond, then, els } => {
                let co = self.operand(cond);
                let to = self.operand(then);
                let fo = self.operand(els);
                self.steps.push(Step::Sel {
                    dst,
                    c: co,
                    t: to,
                    f: fo,
                });
                self.release(co);
                self.release(to);
                self.release(fo);
            }
            _ => unreachable!("leaf expressions are handled above"),
        }
    }
}

/// Compile to a row plan, or `None` when the kernel is outside the
/// vectorisable subset:
///
/// - a read of a *written* argument at a non-centre offset (statement-
///   major row passes would then see cross-point updates the per-point
///   order never produces), or
/// - malformed locals (forward references, > [`MAX_LOCALS`]), or an
///   index dimension > 2.
fn compile(ir: &KernelIr) -> Option<RowPlan> {
    let written: Vec<usize> = ir
        .stmts
        .iter()
        .filter_map(|s| match s {
            Stmt::Store { arg, .. } => Some(*arg),
            _ => None,
        })
        .collect();

    let mut n_locals = 0usize;
    let mut n_args = 0usize;
    let mut n_gbl = 0usize;
    let mut n_red = 0usize;
    let mut accesses: Vec<(usize, [i32; 3])> = Vec::new();
    for s in &ir.stmts {
        let mut ok = true;
        walk(stmt_expr(s), &mut |e| match e {
            Expr::Read { arg, off } => {
                n_args = n_args.max(*arg + 1);
                if !accesses.contains(&(*arg, *off)) {
                    accesses.push((*arg, *off));
                }
                if written.contains(arg) && *off != [0, 0, 0] {
                    ok = false;
                }
            }
            Expr::Local(i) => {
                if *i >= n_locals {
                    ok = false;
                }
            }
            Expr::Gbl(i) => n_gbl = n_gbl.max(*i + 1),
            Expr::Idx(d) => {
                if *d > 2 {
                    ok = false;
                }
            }
            _ => {}
        });
        if !ok {
            return None;
        }
        match s {
            Stmt::Let(_) => {
                n_locals += 1;
                if n_locals > MAX_LOCALS {
                    return None;
                }
            }
            Stmt::Store { arg, .. } => {
                n_args = n_args.max(*arg + 1);
                if !accesses.contains(&(*arg, [0, 0, 0])) {
                    accesses.push((*arg, [0, 0, 0]));
                }
            }
            Stmt::Reduce { slot, .. } => n_red = n_red.max(*slot + 1),
        }
    }

    let mut steps = Vec::with_capacity(ir.stmts.len());
    let mut n_regs = 0usize;
    let mut lets = 0usize;
    for s in &ir.stmts {
        let mut c = Comp::default();
        match s {
            Stmt::Let(e) => {
                c.emit(e, OUT);
                steps.push(PlanStmt::Let {
                    dst: lets,
                    tape: Tape { steps: c.steps },
                });
                lets += 1;
            }
            Stmt::Store { arg, expr } => {
                c.emit(expr, OUT);
                steps.push(PlanStmt::Store {
                    arg: *arg,
                    in_place: expr_reads_arg(expr, *arg),
                    tape: Tape { steps: c.steps },
                });
            }
            Stmt::Reduce { slot, op, expr } => {
                c.emit(expr, OUT);
                steps.push(PlanStmt::Reduce {
                    slot: *slot,
                    op: *op,
                    tape: Tape { steps: c.steps },
                });
            }
        }
        n_regs = n_regs.max(c.n_regs as usize);
    }

    Some(RowPlan {
        steps,
        n_locals,
        n_regs,
        n_args,
        n_gbl,
        n_red,
        accesses,
    })
}

// ------------------------------------------------------------ text form

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Read { arg, off } => {
                write!(f, "(read {arg} {} {} {})", off[0], off[1], off[2])
            }
            Expr::Lit(v) => write!(f, "(lit {v:?})"),
            Expr::Gbl(i) => write!(f, "(gbl {i})"),
            Expr::Idx(d) => write!(f, "(idx {d})"),
            Expr::Local(i) => write!(f, "(loc {i})"),
            Expr::Unary(op, a) => write!(f, "({} {a})", op.name()),
            Expr::Binary(op, a, b) => write!(f, "({} {a} {b})", op.name()),
            Expr::Select { cond, then, els } => write!(f, "(sel {cond} {then} {els})"),
        }
    }
}

fn red_name(op: RedOp) -> &'static str {
    match op {
        RedOp::Sum => "sum",
        RedOp::Min => "min",
        RedOp::Max => "max",
    }
}

impl fmt::Display for Stmt {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Stmt::Let(e) => write!(f, "let {e}"),
            Stmt::Store { arg, expr } => write!(f, "store {arg} {expr}"),
            Stmt::Reduce { slot, op, expr } => {
                write!(f, "reduce {slot} {} {expr}", red_name(*op))
            }
        }
    }
}

impl fmt::Display for KernelIr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, s) in self.stmts.iter().enumerate() {
            if i > 0 {
                writeln!(f)?;
            }
            write!(f, "{s}")?;
        }
        Ok(())
    }
}

struct Parser<'a> {
    toks: Vec<&'a str>,
    pos: usize,
}

impl<'a> Parser<'a> {
    fn next(&mut self) -> Result<&'a str, String> {
        let t = self
            .toks
            .get(self.pos)
            .copied()
            .ok_or_else(|| "unexpected end of input".to_string())?;
        self.pos += 1;
        Ok(t)
    }

    fn expect(&mut self, t: &str) -> Result<(), String> {
        let got = self.next()?;
        if got == t {
            Ok(())
        } else {
            Err(format!("expected '{t}', got '{got}'"))
        }
    }

    fn num<T: std::str::FromStr>(&mut self, what: &str) -> Result<T, String> {
        let t = self.next()?;
        t.parse().map_err(|_| format!("bad {what}: '{t}'"))
    }

    fn expr(&mut self) -> Result<Expr, String> {
        self.expect("(")?;
        let head = self.next()?;
        let e = match head {
            "read" => Expr::Read {
                arg: self.num("arg")?,
                off: [self.num("off")?, self.num("off")?, self.num("off")?],
            },
            "lit" => Expr::Lit(self.num("literal")?),
            "gbl" => Expr::Gbl(self.num("gbl index")?),
            "idx" => Expr::Idx(self.num("idx dim")?),
            "loc" => {
                let i: usize = self.num("local index")?;
                if i >= MAX_LOCALS {
                    return Err(format!("local {i} out of range"));
                }
                Expr::Local(i)
            }
            "neg" | "abs" | "sqrt" => {
                let op = match head {
                    "neg" => UnOp::Neg,
                    "abs" => UnOp::Abs,
                    _ => UnOp::Sqrt,
                };
                Expr::Unary(op, Box::new(self.expr()?))
            }
            "sel" => Expr::Select {
                cond: Box::new(self.expr()?),
                then: Box::new(self.expr()?),
                els: Box::new(self.expr()?),
            },
            _ => {
                let op = match head {
                    "add" => BinOp::Add,
                    "sub" => BinOp::Sub,
                    "mul" => BinOp::Mul,
                    "div" => BinOp::Div,
                    "min" => BinOp::Min,
                    "max" => BinOp::Max,
                    "gt" => BinOp::Gt,
                    "ge" => BinOp::Ge,
                    "lt" => BinOp::Lt,
                    "le" => BinOp::Le,
                    _ => return Err(format!("unknown operator '{head}'")),
                };
                Expr::bin(op, self.expr()?, self.expr()?)
            }
        };
        self.expect(")")?;
        Ok(e)
    }
}

impl KernelIr {
    /// Parse the `Display` text form back into an IR (round-trip tested).
    pub fn parse(src: &str) -> Result<KernelIr, String> {
        let spaced = src.replace('(', " ( ").replace(')', " ) ");
        let mut p = Parser {
            toks: spaced.split_whitespace().collect(),
            pos: 0,
        };
        let mut stmts = Vec::new();
        while p.pos < p.toks.len() {
            match p.next()? {
                "let" => stmts.push(Stmt::Let(p.expr()?)),
                "store" => stmts.push(Stmt::Store {
                    arg: p.num("store arg")?,
                    expr: p.expr()?,
                }),
                "reduce" => {
                    let slot = p.num("reduce slot")?;
                    let op = match p.next()? {
                        "sum" => RedOp::Sum,
                        "min" => RedOp::Min,
                        "max" => RedOp::Max,
                        o => return Err(format!("unknown reduction '{o}'")),
                    };
                    stmts.push(Stmt::Reduce {
                        slot,
                        op,
                        expr: p.expr()?,
                    });
                }
                t => return Err(format!("expected statement, got '{t}'")),
            }
        }
        Ok(KernelIr::new(stmts))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn star_ir() -> KernelIr {
        let mut k = KirBuilder::new();
        let l = k.let_(
            read(0, [-1, 0, 0]) + read(0, [1, 0, 0]) + read(0, [0, -1, 0]) + read(0, [0, 1, 0])
                - lit(4.0) * read(0, [0, 0, 0]),
        );
        let kap = k.let_(read(1, [0, 0, 0]));
        k.store(2, kap * l);
        k.build()
    }

    #[test]
    fn display_parse_round_trip() {
        let mut k = KirBuilder::new();
        let d = k.let_(read(0, [0, 0, 0]).max(lit(1e-12)));
        let s = k.let_(d.clone().gt(lit(0.5)).select(d.clone().sqrt(), -d));
        k.store(1, s.clone() + lit(0.125) * idx(0));
        k.reduce(0, RedOp::Min, s / 2.0);
        let ir = k.build();
        let text = ir.to_string();
        let back = KernelIr::parse(&text).expect("parse");
        assert_eq!(ir, back);
        assert_eq!(text, back.to_string());
    }

    #[test]
    fn star_chain_compiles_to_sum_step() {
        let ir = star_ir();
        let plan = ir.plan().expect("vectorizable");
        assert_eq!(plan.n_locals, 2);
        assert_eq!(plan.n_args, 3);
        let has_sum = plan.steps.iter().any(|s| match s {
            PlanStmt::Let { tape, .. } => tape
                .steps
                .iter()
                .any(|st| matches!(st, Step::Sum { terms, .. } if terms.len() == 4)),
            _ => false,
        });
        assert!(has_sum, "4-point star should fuse into a Sum step: {plan:?}");
    }

    #[test]
    fn axpy_peephole_and_in_place() {
        // u += alpha * lap — reads the written arg at the centre.
        let mut k = KirBuilder::new();
        k.store(0, read(0, [0, 0, 0]) + lit(0.1) * read(1, [0, 0, 0]));
        let ir = k.build();
        let plan = ir.plan().expect("vectorizable");
        match &plan.steps[0] {
            PlanStmt::Store { in_place, tape, .. } => {
                assert!(*in_place, "centre read of the stored arg is in-place");
                assert!(matches!(tape.steps[0], Step::Axpy { .. }), "{tape:?}");
            }
            other => panic!("expected store, got {other:?}"),
        }
    }

    #[test]
    fn offset_read_of_written_arg_falls_back() {
        // d0 = d0[-1] — statement-major row passes would see updated
        // neighbours; must refuse to compile.
        let mut k = KirBuilder::new();
        k.store(0, read(0, [-1, 0, 0]));
        assert!(!k.build().is_vectorizable());
        // …but an offset read of a *read-only* arg is fine.
        let mut k = KirBuilder::new();
        k.store(1, read(0, [-1, 0, 0]));
        assert!(k.build().is_vectorizable());
    }

    #[test]
    fn forward_local_reference_rejected() {
        let ir = KernelIr::new(vec![Stmt::Store {
            arg: 0,
            expr: Expr::Local(0),
        }]);
        assert!(!ir.is_vectorizable());
    }

    #[test]
    fn step_dst_never_aliases_operands() {
        // Deep expression: registers must be reused, but a step's dst
        // must never equal one of its own operand registers.
        let e = ((read(0, [0, 0, 0]) * read(1, [0, 0, 0]) + read(2, [0, 0, 0]).sqrt())
            * (read(0, [1, 0, 0]) - read(1, [1, 0, 0]) * read(2, [1, 0, 0])))
        .max(read(0, [2, 0, 0]) * read(1, [2, 0, 0]));
        let mut k = KirBuilder::new();
        k.store(3, e);
        let ir = k.build();
        let plan = ir.plan().expect("vectorizable");
        for s in &plan.steps {
            let tape = match s {
                PlanStmt::Let { tape, .. }
                | PlanStmt::Store { tape, .. }
                | PlanStmt::Reduce { tape, .. } => tape,
            };
            for st in &tape.steps {
                let (dst, ops): (u32, Vec<Op>) = match st {
                    Step::Mov { dst, a } => (*dst, vec![*a]),
                    Step::Un { dst, a, .. } => (*dst, vec![*a]),
                    Step::Bin { dst, a, b, .. } => (*dst, vec![*a, *b]),
                    Step::Sel { dst, c, t, f } => (*dst, vec![*c, *t, *f]),
                    Step::Sum { dst, terms } => (*dst, terms.clone()),
                    Step::Axpy { dst, base, coef, x } => (*dst, vec![*base, *coef, *x]),
                };
                for o in ops {
                    if let Op::Reg(r) = o {
                        assert_ne!(dst, r, "dst aliases operand reg in {st:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn interpreter_matches_hand_math() {
        use crate::exec::native::run_loop_native;
        use crate::ops::stencil::StencilId;
        use crate::ops::{Access, Arg, BlockId, DataStore, Dataset, DatasetId, LoopInst};

        let d = |id: u32| Dataset {
            id: DatasetId(id),
            block: BlockId(0),
            name: format!("d{id}"),
            size: [6, 4, 1],
            halo_lo: [1, 1, 0],
            halo_hi: [1, 1, 0],
            elem_bytes: 8,
        };
        let datasets = vec![d(0), d(1)];
        let mut store = DataStore::new();
        store.alloc(&datasets[0]);
        store.alloc(&datasets[1]);
        for (i, v) in store.buf_mut(DatasetId(0)).iter_mut().enumerate() {
            *v = i as f64 * 0.5;
        }

        let mut k = KirBuilder::new();
        let s = k.let_(read(0, [-1, 0, 0]) + read(0, [1, 0, 0]));
        k.store(1, s * lit(0.5) + idx(0));
        let ir = Arc::new(k.build());
        let l = LoopInst {
            name: "t".into(),
            block: BlockId(0),
            range: [(0, 6), (0, 4), (0, 1)],
            args: vec![
                Arg::dat(DatasetId(0), StencilId(0), Access::Read),
                Arg::dat(DatasetId(1), StencilId(0), Access::Write),
            ],
            kernel: ir.to_kernel(),
            kernel_ir: Some(ir),
            seq: 0,
            bw_efficiency: 1.0,
        };
        let mut reds = vec![];
        run_loop_native(&l, l.range, &datasets, &mut store, &mut reds);
        let off = |x: isize, y: isize| datasets[0].offset([x, y, 0]) as usize;
        let src = store.buf(DatasetId(0)).to_vec();
        let got = store.buf(DatasetId(1))[datasets[1].offset([2, 1, 0]) as usize];
        let want = (src[off(1, 1)] + src[off(3, 1)]) * 0.5 + 2.0;
        assert_eq!(got, want);
    }
}
