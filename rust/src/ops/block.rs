//! Structured-mesh blocks: the index spaces datasets are defined on.


/// Opaque block handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockId(pub u32);

/// A structured block: a (up to) 3D index space. 2D applications use
/// `size[2] == 1`.
#[derive(Debug, Clone)]
pub struct Block {
    pub id: BlockId,
    pub name: String,
    /// Number of *interior* grid points along each dimension.
    pub size: [usize; 3],
    /// Spatial dimensionality (2 or 3).
    pub dims: usize,
}

impl Block {
    /// Total interior points.
    pub fn points(&self) -> usize {
        self.size[0] * self.size[1] * self.size[2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn points_product() {
        let b = Block {
            id: BlockId(0),
            name: "g".into(),
            size: [10, 20, 3],
            dims: 3,
        };
        assert_eq!(b.points(), 600);
    }
}
