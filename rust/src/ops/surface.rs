//! The declaration/recording/driving surface the applications program
//! against, shared by the legacy [`crate::ops::OpsContext`] shim and the
//! Program/Session API ([`crate::program`]).
//!
//! Splitting the old god-object surface into three capability traits is
//! what lets one app implementation serve every execution style:
//!
//! * [`Declare`] — handle declarations. Implemented by `OpsContext`
//!   (mutable, interleaved with execution) and
//!   [`crate::program::ProgramBuilder`] (frozen at
//!   [`crate::program::ProgramBuilder::freeze`]).
//! * [`Record`] — enqueue parallel loops. Implemented by `OpsContext`
//!   (lazy queue), [`crate::program::Session`] (dynamic recording with
//!   memoised chain analysis) and
//!   [`crate::program::ChainRecorder`] (record-once frozen chains).
//! * [`Drive`] — trigger points and run-lifecycle calls. Implemented by
//!   `OpsContext` and [`crate::program::Session`].

use super::block::BlockId;
use super::dataset::DatasetId;
use super::kernel::Kernel;
use super::kir::KernelIr;
use super::parloop::{Arg, Range3};
use super::reduction::{RedOp, ReductionId};
use super::stencil::StencilId;
use std::sync::Arc;

/// Declaration surface: blocks, datasets, stencils, reductions.
pub trait Declare {
    /// Set the modelled bytes-per-element for *subsequently* declared
    /// datasets (`8 × scale`). On [`crate::program::ProgramBuilder`]
    /// this is the builder-level default that
    /// `decl_dat_elem` overrides per dataset.
    fn set_model_elem_bytes(&mut self, elem_bytes: u64);

    fn decl_block(&mut self, name: &str, size: [usize; 3]) -> BlockId;

    /// Declare a dataset on `block` with interior `size` and halo depths.
    fn decl_dat(
        &mut self,
        block: BlockId,
        name: &str,
        size: [usize; 3],
        halo_lo: [i32; 3],
        halo_hi: [i32; 3],
    ) -> DatasetId;

    fn decl_stencil(&mut self, name: &str, points: Vec<[i32; 3]>) -> StencilId;

    fn decl_reduction(&mut self, name: &str, op: RedOp) -> ReductionId;
}

/// Loop-recording surface: the parallel-loop construct (§3, Fig. 1).
pub trait Record {
    /// [`Record::par_loop`] with an explicit bandwidth-efficiency factor
    /// (relative to the app baseline; models latency-/compute-bound
    /// kernels such as OpenSBLI's dominant RHS evaluation).
    fn par_loop_eff(
        &mut self,
        name: &str,
        block: BlockId,
        range: Range3,
        kernel: Kernel,
        args: Vec<Arg>,
        bw_efficiency: f64,
    );

    /// Record a parallel loop from a declarative [`KernelIr`] body. The
    /// closure is *derived* from the IR ([`KernelIr::to_kernel`]), so
    /// every executor computes the same expression tree; recorders that
    /// keep [`super::LoopInst`]s override this to also attach the IR for
    /// the vector backend. The default derives the closure and drops the
    /// IR (correct, native-only).
    fn par_loop_ir(
        &mut self,
        name: &str,
        block: BlockId,
        range: Range3,
        ir: KernelIr,
        args: Vec<Arg>,
        bw_efficiency: f64,
    ) {
        let ir = Arc::new(ir);
        let kernel = ir.to_kernel();
        self.par_loop_eff(name, block, range, kernel, args, bw_efficiency)
    }

    /// Record a parallel loop. Execution is deferred until a
    /// data-returning call (lazy queues) or until the chain is replayed
    /// (frozen chains).
    fn par_loop(
        &mut self,
        name: &str,
        block: BlockId,
        range: Range3,
        kernel: Kernel,
        args: Vec<Arg>,
    ) {
        self.par_loop_eff(name, block, range, kernel, args, 1.0)
    }
}

/// Driving surface: trigger points (data returned to user space) and
/// run-lifecycle signals.
pub trait Drive: Record {
    /// Execute everything queued (a chain boundary).
    fn flush(&mut self);

    /// Get a reduction result — flushes, then resets the handle.
    fn reduction_result(&mut self, id: ReductionId) -> f64;

    /// Fetch a copy of a dataset's full padded buffer — flushes.
    fn fetch(&mut self, id: DatasetId) -> Vec<f64>;

    /// Read a single value — flushes.
    fn value_at(&mut self, id: DatasetId, idx: [isize; 3]) -> f64;

    /// Periodic halo exchange along `dim` to depth `depth`, between
    /// chains (flushes first).
    fn exchange_periodic(&mut self, id: DatasetId, dim: usize, depth: usize);

    /// §4.1: the application declares that the regular cyclic execution
    /// pattern has begun.
    fn set_cyclic_phase(&mut self, on: bool);

    /// Reset metrics (the paper's timed region excludes initialisation).
    fn reset_metrics(&mut self);
}
