//! Stencils: the sets of relative offsets with which a loop argument
//! accesses its dataset. Stencil extents feed the skewed-tiling slope
//! computation and the tile footprint calculator.


/// Opaque stencil handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StencilId(pub u32);

/// A multi-point stencil: a named list of 3D integer offsets.
///
/// 2D applications use offsets with `z == 0`.
#[derive(Debug, Clone)]
pub struct Stencil {
    pub id: StencilId,
    pub name: String,
    pub points: Vec<[i32; 3]>,
}

impl Stencil {
    /// Minimum offset along each dimension (≤ 0 for typical stencils).
    pub fn min_extent(&self) -> [i32; 3] {
        let mut m = [i32::MAX; 3];
        for p in &self.points {
            for d in 0..3 {
                m[d] = m[d].min(p[d]);
            }
        }
        if self.points.is_empty() {
            [0; 3]
        } else {
            m
        }
    }

    /// Maximum offset along each dimension (≥ 0 for typical stencils).
    pub fn max_extent(&self) -> [i32; 3] {
        let mut m = [i32::MIN; 3];
        for p in &self.points {
            for d in 0..3 {
                m[d] = m[d].max(p[d]);
            }
        }
        if self.points.is_empty() {
            [0; 3]
        } else {
            m
        }
    }

    /// Largest absolute offset along dimension `d` — the stencil *radius*
    /// used for tile skewing along the tiled dimension.
    pub fn radius(&self, d: usize) -> i32 {
        self.points
            .iter()
            .map(|p| p[d].abs())
            .max()
            .unwrap_or(0)
    }
}

/// Convenience constructors for the stencil families the three
/// applications use.
pub mod shapes {
    /// The single-point stencil `(0,0,0)`.
    pub fn point() -> Vec<[i32; 3]> {
        vec![[0, 0, 0]]
    }

    /// 2D star stencil of radius `r` (e.g. `r = 1` gives the 5-point
    /// stencil).
    pub fn star2d(r: i32) -> Vec<[i32; 3]> {
        let mut pts = vec![[0, 0, 0]];
        for k in 1..=r {
            pts.push([k, 0, 0]);
            pts.push([-k, 0, 0]);
            pts.push([0, k, 0]);
            pts.push([0, -k, 0]);
        }
        pts
    }

    /// 3D star stencil of radius `r` (e.g. `r = 1` gives the 7-point
    /// stencil).
    pub fn star3d(r: i32) -> Vec<[i32; 3]> {
        let mut pts = vec![[0, 0, 0]];
        for k in 1..=r {
            for d in 0..3 {
                let mut p = [0i32; 3];
                p[d] = k;
                pts.push(p);
                p[d] = -k;
                pts.push(p);
            }
        }
        pts
    }

    /// Full 2D box stencil over `[lo, hi]` in x and y.
    pub fn box2d(lo: i32, hi: i32) -> Vec<[i32; 3]> {
        let mut pts = Vec::new();
        for y in lo..=hi {
            for x in lo..=hi {
                pts.push([x, y, 0]);
            }
        }
        pts
    }

    /// Explicit offset list (helper for staggered-grid stencils).
    pub fn offsets2d(offs: &[(i32, i32)]) -> Vec<[i32; 3]> {
        offs.iter().map(|&(x, y)| [x, y, 0]).collect()
    }

    /// Explicit offset list, 3D.
    pub fn offsets3d(offs: &[(i32, i32, i32)]) -> Vec<[i32; 3]> {
        offs.iter().map(|&(x, y, z)| [x, y, z]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st(points: Vec<[i32; 3]>) -> Stencil {
        Stencil {
            id: StencilId(0),
            name: "t".into(),
            points,
        }
    }

    #[test]
    fn star2d_has_expected_points() {
        let s = st(shapes::star2d(1));
        assert_eq!(s.points.len(), 5);
        assert_eq!(s.min_extent(), [-1, -1, 0]);
        assert_eq!(s.max_extent(), [1, 1, 0]);
        assert_eq!(s.radius(0), 1);
        assert_eq!(s.radius(2), 0);
    }

    #[test]
    fn star3d_radius2() {
        let s = st(shapes::star3d(2));
        assert_eq!(s.points.len(), 13);
        assert_eq!(s.radius(2), 2);
    }

    #[test]
    fn asymmetric_extents() {
        let s = st(shapes::offsets2d(&[(0, 0), (1, 0), (0, 2)]));
        assert_eq!(s.min_extent(), [0, 0, 0]);
        assert_eq!(s.max_extent(), [1, 2, 0]);
        assert_eq!(s.radius(1), 2);
    }

    #[test]
    fn empty_stencil_is_safe() {
        let s = st(vec![]);
        assert_eq!(s.min_extent(), [0; 3]);
        assert_eq!(s.max_extent(), [0; 3]);
        assert_eq!(s.radius(0), 0);
    }
}
