//! The OPS-style structured-mesh DSL core.
//!
//! Mirrors the abstraction of the OPS library (§3 of the paper): *blocks*
//! connect *datasets*, which are accessed through *stencils* from within
//! *parallel loops*. All user data is owned by the library and referred to
//! through opaque handles; parallel loops carry complete access
//! descriptors (dataset, stencil, read/write mode), which is what makes
//! lazy execution and cross-loop dependency analysis possible.

pub mod access;
pub mod api;
pub mod block;
pub mod dataset;
pub mod kernel;
pub mod kir;
pub mod parloop;
pub mod reduction;
pub mod stencil;
pub mod surface;

pub use access::Access;
#[allow(deprecated)]
pub use api::OpsContext;
pub use surface::{Declare, Drive, Record};
pub use block::{Block, BlockId};
pub use dataset::{DataStore, Dataset, DatasetId};
pub use kernel::{Ctx, Kernel};
pub use kir::{KernelIr, KirBuilder};
pub use parloop::{Arg, LoopInst, Range3};
pub use reduction::{RedOp, Reduction, ReductionId};
pub use stencil::{Stencil, StencilId};
