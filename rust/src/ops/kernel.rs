//! Kernel bodies and the per-point access context.
//!
//! A kernel is the "elemental function" of an OPS parallel loop. It sees
//! its arguments only through the [`Ctx`] accessor — the analogue of
//! OPS's `ACC(...)` macros — which resolves a (argument, stencil-offset)
//! pair to a concrete memory location. Because kernels never see raw
//! arrays, the library is free to reorder iterations (tiling!) and to
//! virtually place data.

#[cfg(debug_assertions)]
use super::access::Access;
use std::sync::Arc;

/// Per-argument view used during execution: a raw base pointer positioned
/// at the *current iteration point*, plus strides.
#[derive(Clone, Copy)]
pub(crate) struct ArgView {
    /// Pointer to the element at the current index.
    pub ptr: *mut f64,
    pub strides: [isize; 3],
    #[cfg(debug_assertions)]
    pub lo: *const f64,
    #[cfg(debug_assertions)]
    pub hi: *const f64, // one past the end
    #[cfg(debug_assertions)]
    pub acc: Access,
}

/// The kernel execution context for one iteration point.
///
/// `r`/`w` (and their 3D variants) access dataset arguments by positional
/// argument index and relative stencil offset; `red` accumulates into
/// reduction slots; `idx` exposes the current grid index (OPS's
/// `ops_arg_idx`).
pub struct Ctx<'a> {
    pub(crate) args: &'a [ArgView],
    pub(crate) red: &'a mut [f64],
    pub(crate) consts: &'a [f64],
    pub(crate) idx: [isize; 3],
    /// x distance from the row origin the views are positioned at (the
    /// executor advances this instead of rewriting every view pointer).
    pub(crate) xoff: isize,
    /// Bitmask of argument indices written *at the current point* —
    /// executors reset it per point. Backs the debug-mode read-access
    /// check's carve-out for write-first data read back after a
    /// same-point write.
    #[cfg(debug_assertions)]
    pub(crate) wrote: u64,
}

impl<'a> Ctx<'a> {
    /// Current iteration index.
    #[inline(always)]
    pub fn idx(&self) -> [isize; 3] {
        self.idx
    }

    #[inline(always)]
    fn addr(&self, a: usize, o: [isize; 3]) -> *mut f64 {
        let v = &self.args[a];
        let off =
            (o[0] + self.xoff) * v.strides[0] + o[1] * v.strides[1] + o[2] * v.strides[2];
        let p = unsafe { v.ptr.offset(off) };
        #[cfg(debug_assertions)]
        {
            assert!(
                (p as *const f64) >= v.lo && (p as *const f64) < v.hi,
                "kernel access out of bounds: arg {a} offset {o:?}"
            );
        }
        p
    }

    /// Read argument `a` at 3D offset `o`.
    #[inline(always)]
    pub fn r3(&self, a: usize, ox: isize, oy: isize, oz: isize) -> f64 {
        #[cfg(debug_assertions)]
        assert!(
            // write-first datasets may be read back within the same loop
            // *after* being written at this point (OPS_WRITE semantics);
            // args ≥ 64 are beyond the tracking mask and get a pass.
            self.args[a].acc.reads() || a >= 64 || self.wrote & (1u64 << a) != 0,
            "kernel reads write-first argument {a} before writing it"
        );
        unsafe { *self.addr(a, [ox, oy, oz]) }
    }

    /// Read argument `a` at 2D offset.
    #[inline(always)]
    pub fn r(&self, a: usize, ox: isize, oy: isize) -> f64 {
        self.r3(a, ox, oy, 0)
    }

    /// Write argument `a` at 3D offset `o`.
    #[inline(always)]
    pub fn w3(&mut self, a: usize, ox: isize, oy: isize, oz: isize, v: f64) {
        #[cfg(debug_assertions)]
        {
            assert!(
                self.args[a].acc.writes(),
                "kernel writes a read-only argument {a}"
            );
            if a < 64 {
                self.wrote |= 1u64 << a;
            }
        }
        unsafe { *self.addr(a, [ox, oy, oz]) = v }
    }

    /// Write argument `a` at 2D offset.
    #[inline(always)]
    pub fn w(&mut self, a: usize, ox: isize, oy: isize, v: f64) {
        self.w3(a, ox, oy, 0, v)
    }

    /// Accumulate into reduction slot `slot` (sum).
    #[inline(always)]
    pub fn red_sum(&mut self, slot: usize, v: f64) {
        self.red[slot] += v;
    }

    /// Min-reduce into reduction slot `slot`.
    #[inline(always)]
    pub fn red_min(&mut self, slot: usize, v: f64) {
        if v < self.red[slot] {
            self.red[slot] = v;
        }
    }

    /// Max-reduce into reduction slot `slot`.
    #[inline(always)]
    pub fn red_max(&mut self, slot: usize, v: f64) {
        if v > self.red[slot] {
            self.red[slot] = v;
        }
    }

    /// Read a global constant passed to the loop (OPS's `ops_arg_gbl` with
    /// read access).
    #[inline(always)]
    pub fn gbl(&self, i: usize) -> f64 {
        self.consts[i]
    }
}

/// A kernel body. Shared (`Arc`) because lazy execution stores loops in a
/// queue and tiling executes each loop many times (once per tile).
pub type Kernel = Arc<dyn Fn(&mut Ctx) + Send + Sync>;

/// Convenience constructor so call sites read `kernel(|c| …)`.
pub fn kernel<F: Fn(&mut Ctx) + Send + Sync + 'static>(f: F) -> Kernel {
    Arc::new(f)
}
