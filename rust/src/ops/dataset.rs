//! Datasets: block-attached fields owned by the library and referred to
//! through opaque handles, plus the backing [`DataStore`].

use super::block::BlockId;

/// Opaque dataset handle — the only thing user code holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DatasetId(pub u32);

/// Metadata for one dataset.
///
/// A dataset covers the index range `[-halo_lo[d], size[d] + halo_hi[d])`
/// along each dimension `d`; staggered-grid fields (e.g. CloverLeaf's
/// vertex-centred velocities) simply declare a larger `size`. Storage is
/// row-major with x fastest.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub id: DatasetId,
    pub block: BlockId,
    pub name: String,
    /// Interior extent along each dimension.
    pub size: [usize; 3],
    /// Halo depth below index 0 (non-negative).
    pub halo_lo: [i32; 3],
    /// Halo depth past `size` (non-negative).
    pub halo_hi: [i32; 3],
    /// Bytes per element in the *modelled* problem (the simulator's byte
    /// accounting is in terms of the paper's double-precision fields).
    pub elem_bytes: u64,
}

impl Dataset {
    /// Padded extent along dimension `d`.
    #[inline]
    pub fn padded(&self, d: usize) -> usize {
        (self.halo_lo[d] + self.size[d] as i32 + self.halo_hi[d]) as usize
    }

    /// Total allocated elements (including halos).
    pub fn alloc_len(&self) -> usize {
        self.padded(0) * self.padded(1) * self.padded(2)
    }

    /// Strides (in elements) for x, y, z.
    #[inline]
    pub fn strides(&self) -> [isize; 3] {
        let sx = 1isize;
        let sy = self.padded(0) as isize;
        let sz = (self.padded(0) * self.padded(1)) as isize;
        [sx, sy, sz]
    }

    /// Flat element offset of logical index `(i, j, k)`.
    ///
    /// Valid logical indices run `-halo_lo[d] ..= size[d] + halo_hi[d] - 1`.
    #[inline]
    pub fn offset(&self, idx: [isize; 3]) -> isize {
        let s = self.strides();
        (idx[0] + self.halo_lo[0] as isize) * s[0]
            + (idx[1] + self.halo_lo[1] as isize) * s[1]
            + (idx[2] + self.halo_lo[2] as isize) * s[2]
    }

    /// Total bytes of this dataset in the modelled problem.
    pub fn bytes(&self) -> u64 {
        self.alloc_len() as u64 * self.elem_bytes
    }

    /// Bytes of one boundary plane of the *modelled* problem, assuming
    /// the paper's (near-isotropic) grids: `total^((d-1)/d)`. Our actual
    /// grids are deliberately tall along the tiled dimension (so skewed
    /// tiles have room), which would otherwise exaggerate surface costs
    /// ~10x; halo-exchange models use this instead of [`Self::plane_bytes`].
    pub fn repr_plane_bytes(&self) -> u64 {
        // modelled double-precision points, independent of the actual
        // grid's aspect ratio or the model-scale factor
        let points = self.bytes() as f64 / 8.0;
        let d = if self.padded(2) > 1 { 3.0 } else { 2.0 };
        (points.powf((d - 1.0) / d) * 8.0) as u64
    }

    /// Bytes of one x–y plane (the unit moved when streaming tiles along
    /// the outermost dimension).
    pub fn plane_bytes(&self, tile_dim: usize) -> u64 {
        let total = self.alloc_len() as u64;
        let extent = self.padded(tile_dim) as u64;
        if extent == 0 {
            0
        } else {
            total / extent * self.elem_bytes
        }
    }
}

/// The backing store for all datasets — plain host memory. The memory
/// engines treat device placement *virtually* (time is simulated), so a
/// single canonical copy is enough and tiled execution can be verified
/// bit-exactly against untiled execution.
#[derive(Debug, Default)]
pub struct DataStore {
    bufs: Vec<Vec<f64>>,
}

impl DataStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate storage for a new dataset; returns nothing — storage is
    /// indexed by `DatasetId` order of declaration.
    pub fn alloc(&mut self, ds: &Dataset) {
        assert_eq!(
            ds.id.0 as usize,
            self.bufs.len(),
            "datasets must be allocated in declaration order"
        );
        self.bufs.push(vec![0.0; ds.alloc_len()]);
    }

    #[inline]
    pub fn buf(&self, id: DatasetId) -> &[f64] {
        &self.bufs[id.0 as usize]
    }

    #[inline]
    pub fn buf_mut(&mut self, id: DatasetId) -> &mut [f64] {
        &mut self.bufs[id.0 as usize]
    }

    /// Raw pointer to a dataset buffer — used by the kernel executor to
    /// build per-argument accessors (several arguments may alias distinct
    /// datasets; aliasing rules are enforced by the loop validator).
    #[inline]
    pub(crate) fn raw(&mut self, id: DatasetId) -> (*mut f64, usize) {
        let b = &mut self.bufs[id.0 as usize];
        (b.as_mut_ptr(), b.len())
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> Dataset {
        Dataset {
            id: DatasetId(0),
            block: BlockId(0),
            name: "d".into(),
            size: [8, 4, 1],
            halo_lo: [2, 2, 0],
            halo_hi: [2, 2, 0],
            elem_bytes: 8,
        }
    }

    #[test]
    fn padded_and_alloc() {
        let d = ds();
        assert_eq!(d.padded(0), 12);
        assert_eq!(d.padded(1), 8);
        assert_eq!(d.padded(2), 1);
        assert_eq!(d.alloc_len(), 96);
        assert_eq!(d.bytes(), 96 * 8);
    }

    #[test]
    fn offset_of_origin_skips_halo() {
        let d = ds();
        // origin (0,0,0) sits at (2,2,0) in padded space.
        assert_eq!(d.offset([0, 0, 0]), 2 + 2 * 12);
        assert_eq!(d.offset([-2, -2, 0]), 0);
        assert_eq!(
            d.offset([(d.size[0] + 1) as isize, 0, 0]),
            2 + 9 + 2 * 12
        );
    }

    #[test]
    fn store_roundtrip() {
        let d = ds();
        let mut st = DataStore::new();
        st.alloc(&d);
        let off = d.offset([3, 1, 0]) as usize;
        st.buf_mut(d.id)[off] = 42.0;
        assert_eq!(st.buf(d.id)[off], 42.0);
    }

    #[test]
    fn plane_bytes_along_y() {
        let d = ds();
        // padded = 12 x 8 x 1; plane along dim 1 = 12 elements.
        assert_eq!(d.plane_bytes(1), 12 * 8);
    }
}
