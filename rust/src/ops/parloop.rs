//! Parallel-loop descriptors — the unit the lazy queue stores and the
//! tiling analysis consumes.

use super::access::Access;
use super::block::BlockId;
use super::dataset::DatasetId;
use super::kernel::Kernel;
use super::kir::KernelIr;
use super::reduction::{RedOp, ReductionId};
use super::stencil::StencilId;
use std::sync::Arc;

/// An iteration range: half-open `[lo, hi)` per dimension. 2D loops use
/// `z = (0, 1)`.
pub type Range3 = [(isize, isize); 3];

/// Number of points in a range.
pub fn range_points(r: &Range3) -> u64 {
    r.iter()
        .map(|&(lo, hi)| (hi - lo).max(0) as u64)
        .product()
}

/// One argument of a parallel loop.
#[derive(Clone)]
pub enum Arg {
    /// A dataset accessed through a stencil with a given mode.
    Dat {
        dat: DatasetId,
        stencil: StencilId,
        acc: Access,
    },
    /// A global reduction (sum/min/max into a handle).
    GblRed { red: ReductionId, op: RedOp },
    /// Loop-invariant scalars visible to the kernel via [`super::Ctx::gbl`].
    GblConst { values: Vec<f64> },
    /// The iteration index (OPS's `ops_arg_idx`); the kernel reads it via
    /// [`super::Ctx::idx`]. Declared for parity with OPS, carries no data.
    Idx,
}

impl Arg {
    pub fn dat(dat: DatasetId, stencil: StencilId, acc: Access) -> Self {
        Arg::Dat { dat, stencil, acc }
    }
}

/// A recorded parallel loop instance.
#[derive(Clone)]
pub struct LoopInst {
    /// Kernel name (diagnostics, metrics, PJRT artifact lookup).
    pub name: String,
    pub block: BlockId,
    pub range: Range3,
    pub args: Vec<Arg>,
    pub kernel: Kernel,
    /// Declarative kernel IR, when the loop was recorded through
    /// [`super::Record::par_loop_ir`]. The closure above is derived from
    /// it, so executors may run either representation; the
    /// [`VectorExecutor`](crate::exec::VectorExecutor) compiles it into
    /// slice-based row loops and falls back to the closure otherwise.
    pub kernel_ir: Option<Arc<KernelIr>>,
    /// Monotonically increasing id assigned at enqueue time.
    pub seq: u64,
    /// Relative cost factor of this kernel: 1.0 = pure streaming
    /// (STREAM-like); < 1.0 models latency-/compute-sensitive kernels
    /// that achieve a fraction of streaming bandwidth (§5.1–§5.3 of the
    /// paper calibrates e.g. OpenSBLI's dominant kernel this way).
    pub bw_efficiency: f64,
}

impl LoopInst {
    /// Bytes moved by this loop according to the paper's §5.1 metric:
    /// iteration points × Σ over dataset args of elem-bytes × (1 for R or
    /// W, 2 for RW/Inc).
    pub fn bytes_touched(&self, elem_bytes: u64) -> u64 {
        let pts = range_points(&self.range);
        let per_point: u64 = self
            .args
            .iter()
            .map(|a| match a {
                Arg::Dat { acc, .. } => elem_bytes * acc.traffic_multiplier(),
                _ => 0,
            })
            .sum();
        pts * per_point
    }

    /// Dataset arguments only, in positional order.
    pub fn dat_args(&self) -> impl Iterator<Item = (DatasetId, StencilId, Access)> + '_ {
        self.args.iter().filter_map(|a| match a {
            Arg::Dat { dat, stencil, acc } => Some((*dat, *stencil, *acc)),
            _ => None,
        })
    }

    /// Does this loop carry a reduction (a chain trigger point)?
    pub fn has_reduction(&self) -> bool {
        self.args.iter().any(|a| matches!(a, Arg::GblRed { .. }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::kernel::kernel;

    fn mkloop(args: Vec<Arg>) -> LoopInst {
        LoopInst {
            name: "t".into(),
            block: BlockId(0),
            range: [(0, 10), (0, 5), (0, 1)],
            args,
            kernel: kernel(|_| {}),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        }
    }

    #[test]
    fn bytes_touched_counts_rw_twice() {
        let l = mkloop(vec![
            Arg::dat(DatasetId(0), StencilId(0), Access::Read),
            Arg::dat(DatasetId(1), StencilId(0), Access::ReadWrite),
            Arg::GblConst { values: vec![1.0] },
        ]);
        // 50 points * (8 + 16) bytes
        assert_eq!(l.bytes_touched(8), 50 * 24);
    }

    #[test]
    fn range_points_empty_is_zero() {
        assert_eq!(range_points(&[(5, 5), (0, 10), (0, 1)]), 0);
        assert_eq!(range_points(&[(7, 5), (0, 10), (0, 1)]), 0);
    }

    #[test]
    fn reduction_detection() {
        let l = mkloop(vec![Arg::GblRed {
            red: ReductionId(0),
            op: RedOp::Min,
        }]);
        assert!(l.has_reduction());
        assert!(!mkloop(vec![]).has_reduction());
    }
}
