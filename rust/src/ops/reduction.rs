//! Global reductions. Requesting a reduction *result* is one of the API
//! calls that returns data to user space and therefore terminates the
//! lazily-queued loop chain (§3 of the paper).


/// Opaque reduction handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ReductionId(pub u32);

/// Reduction operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RedOp {
    Sum,
    Min,
    Max,
}

impl RedOp {
    /// Identity element.
    pub fn identity(self) -> f64 {
        match self {
            RedOp::Sum => 0.0,
            RedOp::Min => f64::INFINITY,
            RedOp::Max => f64::NEG_INFINITY,
        }
    }

    /// Combine two partial results.
    #[inline]
    pub fn combine(self, a: f64, b: f64) -> f64 {
        match self {
            RedOp::Sum => a + b,
            RedOp::Min => a.min(b),
            RedOp::Max => a.max(b),
        }
    }
}

/// A named reduction slot.
#[derive(Debug, Clone)]
pub struct Reduction {
    pub id: ReductionId,
    pub name: String,
    pub op: RedOp,
    pub value: f64,
}

impl Reduction {
    pub fn new(id: ReductionId, name: &str, op: RedOp) -> Self {
        Reduction {
            id,
            name: name.to_string(),
            op,
            value: op.identity(),
        }
    }

    pub fn reset(&mut self) {
        self.value = self.op.identity();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identities() {
        assert_eq!(RedOp::Sum.identity(), 0.0);
        assert_eq!(RedOp::Min.identity(), f64::INFINITY);
        assert_eq!(RedOp::Max.identity(), f64::NEG_INFINITY);
    }

    #[test]
    fn combine_ops() {
        assert_eq!(RedOp::Sum.combine(2.0, 3.0), 5.0);
        assert_eq!(RedOp::Min.combine(2.0, 3.0), 2.0);
        assert_eq!(RedOp::Max.combine(2.0, 3.0), 3.0);
    }

    #[test]
    fn reset_restores_identity() {
        let mut r = Reduction::new(ReductionId(0), "dt", RedOp::Min);
        r.value = 0.5;
        r.reset();
        assert_eq!(r.value, f64::INFINITY);
    }
}
