//! Dataset access modes, mirroring OPS's `OPS_READ` / `OPS_WRITE` /
//! `OPS_RW` / `OPS_INC` descriptors.


/// How a parallel-loop argument accesses its dataset.
///
/// The access mode drives both the dependency analysis (§3) and the
/// data-movement optimisations of §4.1: `Read` datasets are never copied
/// back from the device, `Write` (write-first) datasets are never copied
/// *to* the device, and under the *Cyclic* optimisation write-first
/// datasets are not copied back either.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Access {
    /// Read-only access (`OPS_READ`).
    Read,
    /// Write-first access (`OPS_WRITE`): every point in the iteration
    /// range is written before any read, so the previous contents are
    /// dead on entry.
    Write,
    /// Read-modify-write (`OPS_RW`).
    ReadWrite,
    /// Increment (`OPS_INC`) — commutative accumulation; treated as
    /// read-modify-write for dependencies and byte accounting.
    Inc,
}

impl Access {
    /// Does this access observe the previous contents of the dataset?
    #[inline]
    pub fn reads(self) -> bool {
        !matches!(self, Access::Write)
    }

    /// Does this access modify the dataset?
    #[inline]
    pub fn writes(self) -> bool {
        !matches!(self, Access::Read)
    }

    /// Byte-traffic multiplier used by the paper's Average Bandwidth
    /// metric (§5.1): 1× for pure reads or writes, 2× for read+write.
    #[inline]
    pub fn traffic_multiplier(self) -> u64 {
        match self {
            Access::Read | Access::Write => 1,
            Access::ReadWrite | Access::Inc => 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_write_predicates() {
        assert!(Access::Read.reads() && !Access::Read.writes());
        assert!(!Access::Write.reads() && Access::Write.writes());
        assert!(Access::ReadWrite.reads() && Access::ReadWrite.writes());
        assert!(Access::Inc.reads() && Access::Inc.writes());
    }

    #[test]
    fn traffic_multipliers_match_paper_metric() {
        assert_eq!(Access::Read.traffic_multiplier(), 1);
        assert_eq!(Access::Write.traffic_multiplier(), 1);
        assert_eq!(Access::ReadWrite.traffic_multiplier(), 2);
        assert_eq!(Access::Inc.traffic_multiplier(), 2);
    }
}
