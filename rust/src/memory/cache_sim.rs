//! Direct-mapped cache simulator for KNL's MCDRAM cache mode, plus the
//! virtual address map that places the *modelled* datasets in a flat
//! address space.
//!
//! MCDRAM in cache mode really is a direct-mapped memory-side cache; we
//! simulate it at a coarse granule (default 4 MiB) because stencil sweeps
//! stream contiguous slabs, so intra-granule behaviour is uniform. Miss
//! and writeback traffic feed the DDR4 side of the per-loop time model.

use crate::ops::{Dataset, DatasetId, Range3, Stencil};

/// Assigns each dataset a contiguous region in a virtual (modelled)
/// address space; regions are granule-aligned so conflict behaviour is
/// deterministic.
#[derive(Debug, Clone)]
pub struct AddressMap {
    base: Vec<u64>,
    total: u64,
    granule: u64,
}

impl AddressMap {
    pub fn new(datasets: &[Dataset], granule: u64) -> Self {
        let mut base = Vec::with_capacity(datasets.len());
        let mut cursor = 0u64;
        for ds in datasets {
            base.push(cursor);
            let b = ds.bytes();
            cursor += b.div_ceil(granule) * granule;
        }
        AddressMap {
            base,
            total: cursor,
            granule,
        }
    }

    pub fn base(&self, d: DatasetId) -> u64 {
        self.base[d.0 as usize]
    }

    pub fn total_bytes(&self) -> u64 {
        self.total
    }

    pub fn granule(&self) -> u64 {
        self.granule
    }

    /// The contiguous modelled address range a loop touches in dataset
    /// `d` when executing `range`: whole tile_dim-planes covering the
    /// stencil-extended interval.
    pub fn slab(
        &self,
        ds: &Dataset,
        stencil: &Stencil,
        range: &Range3,
        tile_dim: usize,
    ) -> (u64, u64) {
        let lo_ext = stencil.min_extent()[tile_dim] as isize;
        let hi_ext = stencil.max_extent()[tile_dim] as isize;
        let dlo = -(ds.halo_lo[tile_dim] as isize);
        let dhi = ds.size[tile_dim] as isize + ds.halo_hi[tile_dim] as isize;
        let lo = (range[tile_dim].0 + lo_ext).clamp(dlo, dhi);
        let hi = (range[tile_dim].1 + hi_ext).clamp(dlo, dhi);
        if hi <= lo {
            return (self.base(ds.id), 0);
        }
        let plane = ds.plane_bytes(tile_dim);
        let start = self.base(ds.id) + (lo - dlo) as u64 * plane;
        (start, (hi - lo) as u64 * plane)
    }
}

/// Result of streaming a byte range through the cache.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AccessResult {
    pub hit_bytes: u64,
    pub miss_bytes: u64,
    /// Dirty evictions (DDR4 write traffic).
    pub writeback_bytes: u64,
    pub hit_granules: u64,
    pub miss_granules: u64,
}

impl AccessResult {
    pub fn merge(&mut self, o: AccessResult) {
        self.hit_bytes += o.hit_bytes;
        self.miss_bytes += o.miss_bytes;
        self.writeback_bytes += o.writeback_bytes;
        self.hit_granules += o.hit_granules;
        self.miss_granules += o.miss_granules;
    }

    /// DDR4-side traffic caused by this access.
    pub fn ddr_bytes(&self) -> u64 {
        self.miss_bytes + self.writeback_bytes
    }
}

/// Direct-mapped, write-back, write-allocate-on-partial cache of
/// `capacity` bytes with `granule`-sized lines.
#[derive(Debug, Clone)]
pub struct CacheSim {
    granule: u64,
    sets: usize,
    /// tag per set: granule index + 1 (0 = invalid).
    tags: Vec<u64>,
    dirty: Vec<bool>,
}

impl CacheSim {
    pub fn new(capacity: u64, granule: u64) -> Self {
        let sets = (capacity / granule).max(1) as usize;
        CacheSim {
            granule,
            sets,
            tags: vec![0; sets],
            dirty: vec![false; sets],
        }
    }

    pub fn capacity(&self) -> u64 {
        self.sets as u64 * self.granule
    }

    pub fn reset(&mut self) {
        self.tags.iter_mut().for_each(|t| *t = 0);
        self.dirty.iter_mut().for_each(|d| *d = false);
    }

    /// Stream `[addr, addr+len)` through the cache.
    ///
    /// `read` controls whether a miss fills from DDR4 (pure streaming
    /// stores of write-first outputs allocate without a fill); `write`
    /// marks touched granules dirty so their eviction costs a writeback.
    pub fn access_range(&mut self, addr: u64, len: u64, read: bool, write: bool) -> AccessResult {
        let mut res = AccessResult::default();
        if len == 0 {
            return res;
        }
        let g0 = addr / self.granule;
        let g1 = (addr + len - 1) / self.granule;
        for g in g0..=g1 {
            let set = (g % self.sets as u64) as usize;
            let tag = g + 1;
            if self.tags[set] == tag {
                res.hit_bytes += self.granule;
                res.hit_granules += 1;
                if write {
                    self.dirty[set] = true;
                }
            } else {
                // evict
                if self.tags[set] != 0 && self.dirty[set] {
                    res.writeback_bytes += self.granule;
                }
                self.tags[set] = tag;
                self.dirty[set] = write;
                res.miss_granules += 1;
                if read {
                    res.miss_bytes += self.granule;
                }
                // else: streaming store, allocate without fill
            }
        }
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::BlockId;

    #[test]
    fn second_pass_hits_when_fitting() {
        let mut c = CacheSim::new(1024, 64); // 16 sets
        let first = c.access_range(0, 1024, true, false);
        assert_eq!(first.miss_granules, 16);
        assert_eq!(first.hit_granules, 0);
        let second = c.access_range(0, 1024, true, false);
        assert_eq!(second.hit_granules, 16);
        assert_eq!(second.miss_bytes, 0);
    }

    #[test]
    fn oversubscribed_stream_thrashes() {
        let mut c = CacheSim::new(1024, 64);
        c.access_range(0, 2048, true, false);
        let again = c.access_range(0, 2048, true, false);
        // 2× capacity streamed sequentially through a direct-mapped cache:
        // everything conflicts.
        assert_eq!(again.hit_granules, 0);
        assert_eq!(again.miss_granules, 32);
    }

    #[test]
    fn dirty_eviction_writes_back() {
        let mut c = CacheSim::new(1024, 64);
        c.access_range(0, 1024, false, true); // fill dirty
        let r = c.access_range(1024, 1024, true, false); // conflict-evict all
        assert_eq!(r.writeback_bytes, 1024);
    }

    #[test]
    fn whole_granule_write_skips_fill() {
        let mut c = CacheSim::new(1024, 64);
        let r = c.access_range(0, 256, false, true);
        assert_eq!(r.miss_bytes, 0);
        assert_eq!(r.miss_granules, 4);
    }

    #[test]
    fn address_map_places_disjoint_aligned() {
        let ds = |id: u32, ny: usize| Dataset {
            id: DatasetId(id),
            block: BlockId(0),
            name: format!("d{id}"),
            size: [100, ny, 1],
            halo_lo: [0; 3],
            halo_hi: [0; 3],
            elem_bytes: 8,
        };
        let datasets = vec![ds(0, 10), ds(1, 20)];
        let m = AddressMap::new(&datasets, 4096);
        assert_eq!(m.base(DatasetId(0)), 0);
        assert_eq!(m.base(DatasetId(1)) % 4096, 0);
        assert!(m.base(DatasetId(1)) >= datasets[0].bytes());
        assert!(m.total_bytes() >= datasets[0].bytes() + datasets[1].bytes());
    }

    #[test]
    fn slab_covers_stencil_extension() {
        let ds = Dataset {
            id: DatasetId(0),
            block: BlockId(0),
            name: "d".into(),
            size: [10, 10, 1],
            halo_lo: [2, 2, 0],
            halo_hi: [2, 2, 0],
            elem_bytes: 8,
        };
        let st = Stencil {
            id: crate::ops::StencilId(0),
            name: "s".into(),
            points: crate::ops::stencil::shapes::star2d(1),
        };
        let m = AddressMap::new(std::slice::from_ref(&ds), 4096);
        let (addr, len) = m.slab(&ds, &st, &[(0, 10), (3, 5), (0, 1)], 1);
        let plane = ds.plane_bytes(1);
        // rows 2..6 (stencil extends 3..5 by ±1), offset by halo_lo=2.
        assert_eq!(addr, m.base(DatasetId(0)) + 4 * plane);
        assert_eq!(len, 4 * plane);
    }
}
