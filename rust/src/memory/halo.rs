//! MPI halo-exchange cost model.
//!
//! The paper runs KNL benchmarks with 4 MPI processes and notes (§5.2)
//! that tiling also batches halo exchanges: untiled OPS exchanges halos
//! per loop, tiled OPS computes the chain's aggregate halo once per
//! chain — fewer, larger messages. This small model reproduces that
//! effect (visible at problem sizes that fit in cache).

use crate::ops::{Dataset, LoopInst, Stencil};

#[derive(Debug, Clone)]
pub struct HaloModel {
    /// Per-exchange latency, seconds.
    pub latency_s: f64,
    /// Exchange bandwidth, GB/s (on-chip MPI between quadrants).
    pub bw_gbs: f64,
}

impl HaloModel {
    pub fn knl() -> Self {
        HaloModel {
            latency_s: 8e-6,
            // on-chip MPI between quadrants of one KNL moves through
            // shared MCDRAM/DDR; far faster than a NIC
            bw_gbs: 40.0,
        }
    }

    /// Cost of the per-loop halo exchange in untiled execution: every
    /// dataset argument read through a non-point stencil needs its halo
    /// refreshed. Returns (time, number-of-exchanges).
    pub fn per_loop_cost(
        &self,
        l: &LoopInst,
        datasets: &[Dataset],
        stencils: &[Stencil],
        _tile_dim: usize,
    ) -> (f64, u64) {
        let mut t = 0.0;
        let mut n = 0u64;
        for (d, s, acc) in l.dat_args() {
            if !acc.reads() {
                continue;
            }
            let st = &stencils[s.0 as usize];
            let r = st.radius(0).max(st.radius(1)).max(st.radius(2)) as u64;
            if r == 0 {
                continue;
            }
            let ds = &datasets[d.0 as usize];
            // Two boundary slabs of depth r per partitioned dimension
            // (4 ranks = 2x2 decomposition -> 2 cut dimensions, but a
            // single aggregate term is enough for the model).
            let bytes = 2 * r * ds.repr_plane_bytes();
            t += self.latency_s + bytes as f64 / (self.bw_gbs * 1e9);
            n += 1;
        }
        (t, n)
    }

    /// Cost of the per-chain aggregate exchange in tiled execution: one
    /// exchange per touched dataset, of depth = the chain's skew depth.
    pub fn per_chain_cost(
        &self,
        chain: &[LoopInst],
        datasets: &[Dataset],
        stencils: &[Stencil],
        _tile_dim: usize,
        max_shift: isize,
    ) -> (f64, u64) {
        let mut seen = vec![false; datasets.len()];
        let mut t = 0.0;
        let mut n = 0u64;
        for l in chain {
            for (d, s, acc) in l.dat_args() {
                if !acc.reads() || seen[d.0 as usize] {
                    continue;
                }
                let st = &stencils[s.0 as usize];
                let r = st.radius(0).max(st.radius(1)).max(st.radius(2)) as i64;
                if r == 0 {
                    continue;
                }
                seen[d.0 as usize] = true;
                let depth = (r + max_shift as i64).max(1) as u64;
                let ds = &datasets[d.0 as usize];
                let bytes = 2 * depth * ds.repr_plane_bytes();
                t += self.latency_s + bytes as f64 / (self.bw_gbs * 1e9);
                n += 1;
            }
        }
        (t, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::ops::{Access, Arg, BlockId, DatasetId};

    fn fixture() -> (Vec<Dataset>, Vec<Stencil>, Vec<LoopInst>) {
        let ds = Dataset {
            id: DatasetId(0),
            block: BlockId(0),
            name: "d".into(),
            size: [100, 100, 1],
            halo_lo: [2, 2, 0],
            halo_hi: [2, 2, 0],
            elem_bytes: 8,
        };
        let stencils = vec![
            Stencil {
                id: StencilId(0),
                name: "pt".into(),
                points: shapes::point(),
            },
            Stencil {
                id: StencilId(1),
                name: "star".into(),
                points: shapes::star2d(1),
            },
        ];
        let mk = |st: u32, acc: Access| LoopInst {
            name: "l".into(),
            block: BlockId(0),
            range: [(0, 100), (0, 100), (0, 1)],
            args: vec![Arg::dat(DatasetId(0), StencilId(st), acc)],
            kernel: kernel(|_| {}),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        };
        (
            vec![ds],
            stencils,
            vec![mk(1, Access::Read), mk(1, Access::Read), mk(0, Access::Write)],
        )
    }

    #[test]
    fn point_stencils_and_writes_need_no_exchange() {
        let (datasets, stencils, chain) = fixture();
        let h = HaloModel::knl();
        let (_, n) = h.per_loop_cost(&chain[2], &datasets, &stencils, 1);
        assert_eq!(n, 0);
    }

    #[test]
    fn tiled_chain_exchanges_once_per_dataset() {
        let (datasets, stencils, chain) = fixture();
        let h = HaloModel::knl();
        // Untiled: one exchange per reading loop = 2.
        let untiled: u64 = chain
            .iter()
            .map(|l| h.per_loop_cost(l, &datasets, &stencils, 1).1)
            .sum();
        assert_eq!(untiled, 2);
        // Tiled: dataset 0 exchanged once.
        let (_, n) = h.per_chain_cost(&chain, &datasets, &stencils, 1, 3);
        assert_eq!(n, 1);
    }

    #[test]
    fn fewer_exchanges_but_larger_when_tiled() {
        let (datasets, stencils, chain) = fixture();
        let h = HaloModel::knl();
        let (t_untiled, _) = h.per_loop_cost(&chain[0], &datasets, &stencils, 1);
        let (t_tiled, _) = h.per_chain_cost(&chain, &datasets, &stencils, 1, 5);
        // The single tiled exchange moves more bytes than one untiled
        // exchange (depth includes the skew), but replaces many of them.
        assert!(t_tiled > t_untiled);
        assert!(t_tiled < 2.0 * t_untiled + h.latency_s);
    }
}
