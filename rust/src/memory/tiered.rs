//! The generic N-tier out-of-core streaming engine.
//!
//! [`TieredEngine`] lowers *any* [`Topology`] onto the discrete-event
//! timeline by applying the paper's Algorithm-1 tiling **recursively at
//! every capacity boundary**: the chain is tiled to fit slots of the
//! outermost bounded tier and streamed over that tier's link; inside
//! each outer tile the restricted sub-chain is tiled again to the next
//! tier down, and so on until the fastest tier, where the tiles
//! actually execute. Each boundary gets its own upload/download stream
//! pair, its own [`PlanSource`] (the auto-tuner injects searched tile
//! counts at the innermost level), and the §4.1 skip-list data-movement
//! elision — read-only datasets are never downloaded, write-first never
//! uploaded, at *every* level.
//!
//! For a two-tier topology the recursion degenerates to exactly the
//! schedule [`super::GpuExplicitEngine`] builds — the same plan, the
//! same events in the same order, the same float arithmetic — so the
//! `gpu-explicit-*` presets routed through this engine reproduce the
//! legacy engine's modelled clocks bit-for-bit
//! (`tests/tiling_equivalence.rs` pins this). A three-tier
//! HBM→host→NVMe stack models problems larger than *host* DRAM: the
//! paper's "beyond 16 GB", extended to "beyond DRAM".
//!
//! Data lives in the **fastest tier that holds the whole chain** —
//! never faster than tier 1, matching the two-tier engines where data
//! always starts on the host side. Boundaries below the home tier are
//! inactive: a three-tier HBM→host→NVMe stack behaves *exactly* like
//! the two-tier machine while the problem fits host DRAM, and only
//! starts paying the NVMe stream once it no longer does. Every chain
//! streams its working set down through the active boundaries and
//! writes results back up, minus whatever the skip lists and
//! cross-chain prefetch credit elide.

use super::calib_util::{chain_bw_norm, elem_bytes, GB};
use super::gpu_explicit::{tile_traffic, GpuOpts};
use crate::codec::CodecSpec;
use crate::exec::timeline::{EventKind, ResourceId, StreamClass, Timeline};
use crate::exec::{Engine, World};
use crate::ops::LoopInst;
use crate::tiling::analysis::ChainAnalysis;
use crate::tiling::plan::{plan_auto_with, plan_chain_with, PlanSource, TilePlan};
use crate::topology::Topology;
use std::sync::Arc;

/// The generic tiered streaming engine.
pub struct TieredEngine {
    /// The memory stack this engine schedules against.
    pub topo: Topology,
    /// Calibrated achieved compute bandwidth of the modelled device,
    /// GB/s (the per-app §5.1 baseline; NVLink presets arrive with the
    /// §5.3 clock boost already folded in).
    pub compute_bw_gbs: f64,
    /// Kernel launch overhead, seconds.
    pub launch_s: f64,
    /// §4.1 optimisation switches, applied at every level.
    pub opts: GpuOpts,
    /// Per-boundary tile-plan sources, innermost (fastest boundary)
    /// first. `plans[0]` is where the auto-tuner injects fixed counts;
    /// everything defaults to [`PlanSource::Auto`].
    pub plans: Vec<PlanSource>,
    /// Prefetch credit carried from the previous chain (innermost
    /// level, as in Algorithm 1).
    prefetch_credit: f64,
    /// Bytes speculatively uploaded for the next chain (diagnostics).
    pub speculative_bytes: u64,
}

/// Per-chain scheduling state threaded through the level recursion.
struct SchedState {
    /// Unspent prefetch credit (applies to the chain's very first
    /// innermost upload only).
    credit: f64,
    /// Whether that first innermost upload happened yet.
    first_seen: bool,
    /// Bytes of the chain's first innermost tile upload — what the next
    /// chain's speculation can cover.
    first_upload_bytes: u64,
    /// Duration of the last executed tile's compute (the prefetch
    /// overlap window for the next chain).
    last_tile_compute: f64,
}

/// Per-chain constants shared by every recursion level.
struct Ctx<'a> {
    norm: f64,
    skip_upload: &'a [bool],
    skip_download: &'a [bool],
    tile_dim: usize,
    tracing: bool,
    s0: ResourceId,
    ups: Vec<ResourceId>,
    downs: Vec<ResourceId>,
    /// Per-level link codec (identity codecs stripped, so `None` here
    /// means the legacy byte-identical code path) and its codec stream.
    codecs: Vec<Option<CodecSpec>>,
    cods: Vec<Option<ResourceId>>,
    /// Tracing label prefix per level (empty for two-tier stacks, which
    /// keep the legacy `tile N` labels).
    prefix: Vec<String>,
}

impl TieredEngine {
    /// Build the engine for a topology. `compute_bw_gbs` is the
    /// app-calibrated achieved bandwidth, `launch_s` the kernel launch
    /// overhead; `opts` validates like the legacy GPU engine's.
    pub fn new(
        topo: Topology,
        compute_bw_gbs: f64,
        launch_s: f64,
        opts: GpuOpts,
    ) -> crate::Result<Self> {
        opts.validate()?;
        crate::ensure!(
            compute_bw_gbs.is_finite() && compute_bw_gbs > 0.0,
            "compute bandwidth must be a positive finite GB/s figure, got {compute_bw_gbs}"
        );
        let plans = vec![PlanSource::Auto; topo.num_tiers().saturating_sub(1)];
        Ok(TieredEngine {
            topo,
            compute_bw_gbs,
            launch_s,
            opts,
            plans,
            prefetch_credit: 0.0,
            speculative_bytes: 0,
        })
    }

    /// Number of capacity boundaries (= streaming levels).
    pub fn levels(&self) -> usize {
        self.topo.num_tiers() - 1
    }

    /// The per-slot byte budget at boundary `level` — an equal share of
    /// the level's (fast-side) tier with the same headroom the legacy
    /// engine leaves for OPS bookkeeping. Every tier above the home
    /// tier is validated finite, so this never falls back in practice.
    pub fn slot_target(&self, level: usize) -> u64 {
        slot_target_for(&self.topo, self.opts.slots, level)
    }

    fn compute_time(&self, l: &LoopInst, bytes: u64, norm: f64) -> f64 {
        bytes as f64 / (self.compute_bw_gbs * l.bw_efficiency * norm * GB) + self.launch_s
    }
}

/// [`TieredEngine::slot_target`] as a free function, so callers that
/// only need the budget arithmetic (the tuner's heuristic seeding)
/// don't have to construct a throwaway engine.
pub fn slot_target_for(topo: &Topology, slots: u8, level: usize) -> u64 {
    let nslots = slots.clamp(2, 3) as f64;
    match topo.tier(level).capacity_bytes {
        Some(cap) => (cap as f64 / nslots * 0.92) as u64,
        None => u64::MAX,
    }
}

impl TieredEngine {
    /// Build the tile plan for one level. The outermost level (the only
    /// one whose chain is the full analysed chain) goes through the
    /// analysis' memoised [`PlanSource::plan_analyzed`] — the exact
    /// call, fallback included, the legacy engine makes — while inner
    /// levels plan their restricted sub-chains directly, reusing the
    /// parent analysis' tiled dimension and skew shifts.
    #[allow(clippy::too_many_arguments)]
    fn level_plan(
        &self,
        level: usize,
        chain: &[LoopInst],
        shifts: &[isize],
        tile_dim: usize,
        analysis: Option<&ChainAnalysis>,
        world: &World<'_>,
    ) -> Arc<TilePlan> {
        let src = self.plans.get(level).copied().unwrap_or(PlanSource::Auto);
        let target = self.slot_target(level);
        match analysis {
            Some(a) => {
                let mut plan =
                    src.plan_analyzed(chain, world.datasets, world.stencils, target, a);
                if matches!(src, PlanSource::Fixed(_))
                    && plan.max_footprint_bytes(world.datasets) > target
                {
                    // A fixed count must honour the slot-capacity
                    // contract; over-budget requests fall back to auto
                    // sizing (the tuner can never win by overflowing).
                    plan = PlanSource::Auto.plan_analyzed(
                        chain,
                        world.datasets,
                        world.stencils,
                        target,
                        a,
                    );
                }
                plan
            }
            None => {
                let auto = || {
                    plan_auto_with(chain, world.datasets, world.stencils, target, tile_dim, shifts)
                        .unwrap_or_else(|_| {
                            plan_chain_with(
                                chain,
                                world.datasets,
                                world.stencils,
                                usize::MAX,
                                tile_dim,
                                shifts,
                            )
                        })
                };
                let built = match src {
                    PlanSource::Fixed(n) => {
                        let p = plan_chain_with(
                            chain,
                            world.datasets,
                            world.stencils,
                            n,
                            tile_dim,
                            shifts,
                        );
                        if p.max_footprint_bytes(world.datasets) > target {
                            auto()
                        } else {
                            p
                        }
                    }
                    PlanSource::Auto => auto(),
                };
                Arc::new(built)
            }
        }
    }

    /// Schedule `chain` at `level`: stream tiles over this boundary's
    /// link, executing (level 0) or recursing (level > 0) inside each.
    #[allow(clippy::too_many_arguments)]
    fn run_level(
        &self,
        level: usize,
        chain: &[LoopInst],
        shifts: &[isize],
        analysis: Option<&ChainAnalysis>,
        world: &mut World<'_>,
        tl: &mut Timeline,
        ctx: &Ctx<'_>,
        st: &mut SchedState,
    ) {
        let plan = self.level_plan(level, chain, shifts, ctx.tile_dim, analysis, world);
        let nt = plan.num_tiles();
        let lsp = crate::obs::span("level");
        lsp.field("tier", &self.topo.tier(level).name);
        lsp.field("tiles", nt);
        if level == 0 {
            world.metrics.tiles += nt as u64;
        }
        let su = ctx.ups[level];
        let sd = ctx.downs[level];
        let link = self.topo.link(level);
        let codec = ctx.codecs[level];
        let pre = &ctx.prefix[level];

        // One boundary crossing with a codec: compress on the sending
        // side, ship the wire bytes, decompress on the receiving side —
        // three chained events, with the transfer stream's cursor moved
        // to decompress-end so every existing consumer wait sees the
        // *usable* tile, while the stream's busy time (and so `util_*`)
        // stays pure wire time. Saved bytes go to the codec ledger;
        // `h2d/d2h_bytes` keep logical bytes and the stream's own byte
        // ledger carries what actually crossed the link.
        let codec_xfer = |tl: &mut Timeline,
                          world: &mut World<'_>,
                          c: &CodecSpec,
                          sx: ResourceId,
                          kind: EventKind,
                          lbl: &str,
                          ready: f64,
                          time_s: f64,
                          logical: u64,
                          wire: u64| {
            let sc = ctx.cods[level].expect("codec stream exists when a codec is attached");
            let c_end = tl.push_at(sc, EventKind::Compress, lbl, ready, c.compress_time_s(logical), logical);
            let x_end = tl.push_at(sx, kind, lbl, c_end, time_s, wire);
            let d_end = tl.push_at(sc, EventKind::Decompress, lbl, x_end, c.decompress_time_s(logical), logical);
            tl.wait_until(sx, d_end);
            world.metrics.codec_bytes_saved += logical - wire;
        };

        // ---- stage in the first tile of this (sub-)chain.
        let tr0 = tile_traffic(&plan, 0, world.datasets, ctx.skip_upload, ctx.skip_download);
        let wire0 = match &codec {
            Some(c) => c.wire_bytes(tr0.upload),
            None => tr0.upload,
        };
        let mut up_time = link.time_s(wire0);
        if level == 0 && !st.first_seen {
            st.first_seen = true;
            st.first_upload_bytes = tr0.upload;
            if self.opts.prefetch && st.credit > 0.0 {
                let credit = st.credit.min(up_time);
                up_time -= credit;
                st.credit = 0.0;
            }
        }
        if level == 0 {
            world.metrics.h2d_bytes += tr0.upload;
        }
        if tr0.upload > 0 || up_time > 0.0 {
            let lbl = if ctx.tracing {
                format!("{pre}tile 0")
            } else {
                String::new()
            };
            match &codec {
                Some(c) => {
                    let ready = tl.cursor(su);
                    codec_xfer(tl, world, c, su, EventKind::Upload, &lbl, ready, up_time, tr0.upload, wire0);
                }
                None => {
                    tl.push(su, EventKind::Upload, &lbl, up_time, tr0.upload);
                }
            }
        }

        for t in 0..nt {
            let label = |what: &str| -> String {
                if ctx.tracing {
                    format!("{pre}{what} {t}")
                } else {
                    String::new()
                }
            };
            // ---- preparation: with 2 slots the upload stream doubles as
            // the download stream (shared staging slot); then the
            // consumer of this boundary waits for the staged tile, and
            // the next tile's upload is issued.
            if self.opts.slots < 3 {
                tl.wait(su, sd);
            }
            let consumer = if level == 0 { ctx.s0 } else { ctx.ups[level - 1] };
            tl.wait(consumer, su);
            if t + 1 < nt {
                let trn =
                    tile_traffic(&plan, t + 1, world.datasets, ctx.skip_upload, ctx.skip_download);
                if trn.upload > 0 {
                    let lbl = if ctx.tracing {
                        format!("{pre}tile {}", t + 1)
                    } else {
                        String::new()
                    };
                    match &codec {
                        Some(c) => {
                            let wire = c.wire_bytes(trn.upload);
                            let ready = tl.cursor(su);
                            codec_xfer(
                                tl,
                                world,
                                c,
                                su,
                                EventKind::Upload,
                                &lbl,
                                ready,
                                link.time_s(wire),
                                trn.upload,
                                wire,
                            );
                        }
                        None => {
                            tl.push(su, EventKind::Upload, &lbl, link.time_s(trn.upload), trn.upload);
                        }
                    }
                }
                if level == 0 {
                    world.metrics.h2d_bytes += trn.upload;
                }
            }

            // ---- body: execute on the fastest tier, or recurse one
            // boundary down with the chain restricted to this tile.
            if level == 0 {
                let tsp = crate::obs::span("tile");
                tsp.field("t", t);
                let mut tile_compute = 0.0;
                let mut tile_bytes_sum = 0u64;
                for (li, r) in plan.tiles[t].loop_ranges.iter().enumerate() {
                    let Some(r) = r else { continue };
                    let l = &chain[li];
                    world
                        .exec
                        .run_loop(l, *r, world.datasets, world.store, world.reds);
                    let frac = crate::ops::parloop::range_points(r) as f64
                        / crate::ops::parloop::range_points(&l.range).max(1) as f64;
                    let bytes = (l.bytes_touched(elem_bytes(world, l)) as f64 * frac) as u64;
                    let ct = self.compute_time(l, bytes, ctx.norm);
                    world.metrics.record_loop(&l.name, bytes, ct);
                    tile_compute += ct;
                    tile_bytes_sum += bytes;
                }
                tl.push(ctx.s0, EventKind::Compute, &label("tile"), tile_compute, tile_bytes_sum);
                world.metrics.obs.record("tile_compute_s", tile_compute);
                st.last_tile_compute = tile_compute;
            } else {
                let mut sub_chain: Vec<LoopInst> = Vec::new();
                let mut sub_shifts: Vec<isize> = Vec::new();
                for (li, r) in plan.tiles[t].loop_ranges.iter().enumerate() {
                    let Some(r) = r else { continue };
                    let mut l = chain[li].clone();
                    l.range = *r;
                    sub_chain.push(l);
                    sub_shifts.push(shifts[li]);
                }
                if !sub_chain.is_empty() {
                    self.run_level(level - 1, &sub_chain, &sub_shifts, None, world, tl, ctx, st);
                }
            }

            // ---- finishing: edge-copy the overlap forward within this
            // tier, then stream the finished writes back over the link.
            let finisher = if level == 0 { ctx.s0 } else { ctx.downs[level - 1] };
            tl.wait(finisher, sd);
            let tr = tile_traffic(&plan, t, world.datasets, ctx.skip_upload, ctx.skip_download);
            if tr.edge > 0 {
                let edge_stream = if level == 0 { ctx.s0 } else { su };
                tl.push(
                    edge_stream,
                    EventKind::EdgeCopy,
                    &label("edge"),
                    tr.edge as f64 / (self.topo.tier(level).bw_gbs * GB),
                    tr.edge,
                );
            }
            if level == 0 {
                world.metrics.d2d_bytes += tr.edge;
            }
            if tr.download > 0 {
                match &codec {
                    Some(c) => {
                        let wire = c.wire_bytes(tr.download);
                        // the tile is ready for compression once the
                        // finisher handed it over (the wait above moved
                        // sd's cursor there)
                        let ready = tl.cursor(sd);
                        codec_xfer(
                            tl,
                            world,
                            c,
                            sd,
                            EventKind::Download,
                            &label("tile"),
                            ready,
                            link.time_s(wire),
                            tr.download,
                            wire,
                        );
                    }
                    None => {
                        tl.push(sd, EventKind::Download, &label("tile"), link.time_s(tr.download), tr.download);
                    }
                }
            }
            if level == 0 {
                world.metrics.d2h_bytes += tr.download;
            }
        }
    }
}

impl Engine for TieredEngine {
    fn run_chain(&mut self, chain: &[LoopInst], world: &mut World<'_>, cyclic_phase: bool) {
        self.run_chain_analyzed(chain, None, world, cyclic_phase);
    }

    fn run_chain_analyzed(
        &mut self,
        chain: &[LoopInst],
        analysis: Option<&ChainAnalysis>,
        world: &mut World<'_>,
        cyclic_phase: bool,
    ) {
        world.metrics.chains += 1;
        let sp = crate::obs::span("tiered");
        sp.field("loops", chain.len());
        let mut local = None;
        let analysis =
            ChainAnalysis::resolve(analysis, &mut local, chain, world.datasets, world.stencils);
        let norm = chain_bw_norm(world, chain);
        // The chain's home tier: the fastest tier that holds its whole
        // working set, but never tier 0 (chains always stage into the
        // fastest tier, as in the two-tier engines). Boundaries at and
        // below the home tier stay silent, so a three-tier stack is
        // bit-identical to its two-tier prefix while the problem fits
        // host DRAM.
        let mut levels = self.levels().min(1);
        while levels < self.levels() {
            match self.topo.tier(levels).capacity_bytes {
                Some(cap) if analysis.chain_bytes > cap => levels += 1,
                _ => break,
            }
        }
        let mut tl = Timeline::for_world(world);

        if levels == 0 {
            // Flat single tier: nothing to stream, one compute event per
            // loop at the calibrated bandwidth.
            let s0 = tl.resource("compute", StreamClass::Compute);
            for l in chain {
                world
                    .exec
                    .run_loop(l, l.range, world.datasets, world.store, world.reds);
                let bytes = l.bytes_touched(elem_bytes(world, l));
                let ct = self.compute_time(l, bytes, norm);
                world.metrics.record_loop(&l.name, bytes, ct);
                let lbl = if tl.tracing() { l.name.clone() } else { String::new() };
                tl.push(s0, EventKind::Compute, &lbl, ct, bytes);
            }
            world.metrics.absorb_timeline(tl);
            self.prefetch_credit = 0.0;
            return;
        }

        // §4.1 data-movement classification, applied at every level.
        let nd = world.datasets.len();
        let mut skip_upload = vec![false; nd];
        let mut skip_download = vec![false; nd];
        for (id, info) in &analysis.summary {
            let d = id.0 as usize;
            skip_upload[d] = info.skip_upload();
            skip_download[d] =
                info.skip_download() || (self.opts.cyclic && cyclic_phase && info.write_first);
        }

        // Streams: one compute resource plus an upload/download pair per
        // active boundary. Two-tier stacks keep the legacy
        // `upload`/`download` names (and therefore the legacy
        // attribution rows); deeper stacks name streams after the
        // receiving tier, whether or not every boundary is active for
        // this chain.
        let two_tier = self.topo.num_tiers() == 2;
        let s0 = tl.resource("compute", StreamClass::Compute);
        let mut ups = Vec::with_capacity(levels);
        let mut downs = Vec::with_capacity(levels);
        let mut codecs = Vec::with_capacity(levels);
        let mut cods = Vec::with_capacity(levels);
        let mut prefix = Vec::with_capacity(levels);
        for l in 0..levels {
            let (un, dn, pre) = if two_tier {
                ("upload".to_string(), "download".to_string(), String::new())
            } else {
                let tn = &self.topo.tier(l).name;
                (
                    format!("{tn}:upload"),
                    format!("{tn}:download"),
                    format!("{tn} "),
                )
            };
            ups.push(tl.resource(&un, StreamClass::Upload));
            downs.push(tl.resource(&dn, StreamClass::Download));
            // Identity codecs are stripped here so the scheduling below
            // takes the exact legacy code path (the ratio-1.0
            // bit-identical bar).
            let codec = self.topo.codec(l).filter(|c| !c.is_identity());
            cods.push(codec.as_ref().map(|_| {
                let cn = if two_tier {
                    "codec".to_string()
                } else {
                    format!("{}:codec", self.topo.tier(l).name)
                };
                tl.resource(&cn, StreamClass::Codec)
            }));
            codecs.push(codec);
            prefix.push(pre);
        }
        let ctx = Ctx {
            norm,
            skip_upload: &skip_upload,
            skip_download: &skip_download,
            tile_dim: analysis.tile_dim,
            tracing: tl.tracing(),
            s0,
            ups,
            downs,
            codecs,
            cods,
            prefix,
        };
        let mut st = SchedState {
            credit: self.prefetch_credit,
            first_seen: false,
            first_upload_bytes: 0,
            last_tile_compute: 0.0,
        };
        self.run_level(
            levels - 1,
            chain,
            &analysis.shifts,
            Some(analysis),
            world,
            &mut tl,
            &ctx,
            &mut st,
        );
        world.metrics.absorb_timeline(tl);

        // Cross-chain speculation: the next chain's first innermost
        // upload overlaps this chain's last tile execution (§4.1).
        if self.opts.prefetch {
            self.prefetch_credit = st.last_tile_compute;
            self.speculative_bytes += st
                .first_upload_bytes
                .min((st.last_tile_compute * self.topo.link(0).bw_gbs * GB) as u64);
        } else {
            self.prefetch_credit = 0.0;
        }
    }

    fn reset_transient(&mut self) {
        self.prefetch_credit = 0.0;
        self.speculative_bytes = 0;
    }

    fn describe(&self) -> String {
        format!(
            "Tiered {} [{} tiers] {}{}",
            self.topo.label(),
            self.topo.num_tiers(),
            if self.opts.cyclic { "Cyclic" } else { "NoCyclic" },
            if self.opts.prefetch { " Prefetch" } else { " NoPrefetch" },
        )
    }

    /// The problem must fit the home (slowest) tier — everything above
    /// it is streamed through.
    fn fits(&self, problem_bytes: u64) -> bool {
        self.topo.fits(problem_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{Executor, Metrics, NativeExecutor};
    use crate::memory::hierarchy::{AppCalib, GpuCalib, Link};
    use crate::memory::GpuExplicitEngine;
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::ops::*;
    use crate::topology::{LinkSpec, Tier};

    const APP: AppCalib = AppCalib::CLOVERLEAF_2D;

    /// Chain: temp = f(state); state' = g(temp, state) — a read-only
    /// coords field, a write-first temp and a read-write state (the
    /// same shape the GPU-explicit engine tests use).
    fn fixture(ny: usize) -> (Vec<Dataset>, Vec<Stencil>, DataStore, Vec<LoopInst>) {
        let mut datasets = vec![];
        let mut store = DataStore::new();
        for (i, name) in ["state", "temp", "coords"].iter().enumerate() {
            let d = Dataset {
                id: DatasetId(i as u32),
                block: BlockId(0),
                name: name.to_string(),
                size: [64, ny, 1],
                halo_lo: [2, 2, 0],
                halo_hi: [2, 2, 0],
                elem_bytes: 8,
            };
            store.alloc(&d);
            datasets.push(d);
        }
        let stencils = vec![
            Stencil {
                id: StencilId(0),
                name: "pt".into(),
                points: shapes::point(),
            },
            Stencil {
                id: StencilId(1),
                name: "star".into(),
                points: shapes::star2d(1),
            },
        ];
        let range: Range3 = [(0, 64), (0, ny as isize), (0, 1)];
        let chain = vec![
            LoopInst {
                name: "mk_temp".into(),
                block: BlockId(0),
                range,
                args: vec![
                    Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(2), StencilId(0), Access::Read),
                    Arg::dat(DatasetId(1), StencilId(0), Access::Write),
                ],
                kernel: kernel(|c| {
                    let v = c.r(0, -1, 0) + c.r(0, 1, 0) + c.r(1, 0, 0);
                    c.w(2, 0, 0, v * 0.25);
                }),
                kernel_ir: None,
                seq: 0,
                bw_efficiency: 1.0,
            },
            LoopInst {
                name: "update".into(),
                block: BlockId(0),
                range,
                args: vec![
                    Arg::dat(DatasetId(1), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(0), StencilId(0), Access::ReadWrite),
                ],
                kernel: kernel(|c| {
                    let v = c.r(0, 0, -1) + c.r(0, 0, 1);
                    let s = c.r(1, 0, 0);
                    c.w(1, 0, 0, s + 0.1 * v);
                }),
                kernel_ir: None,
                seq: 1,
                bw_efficiency: 1.0,
            },
        ];
        (datasets, stencils, store, chain)
    }

    const SMALL_HBM: u64 = 256 << 10;

    fn gpu_two_tier(hbm: u64, link: Link) -> Topology {
        let g = GpuCalib::default();
        Topology::new(
            None,
            vec![
                Tier::new("hbm", Some(hbm), g.bw_device),
                Tier::new("host", None, link.spec().bw_gbs),
            ],
            vec![link.spec()],
        )
        .unwrap()
    }

    fn run_engine(e: &mut dyn Engine, chains: usize, cyclic: bool) -> (Metrics, Vec<Vec<f64>>) {
        let (datasets, stencils, mut store, chain) = fixture(512);
        let mut reds = vec![];
        let mut metrics = Metrics::new();
        let mut exec = NativeExecutor::new();
        for _ in 0..chains {
            let mut world = World {
                datasets: &datasets,
                stencils: &stencils,
                store: &mut store,
                reds: &mut reds,
                metrics: &mut metrics,
                exec: &mut exec,
            };
            e.run_chain(&chain, &mut world, cyclic);
        }
        let bufs = datasets.iter().map(|d| store.buf(d.id).to_vec()).collect();
        (metrics, bufs)
    }

    #[test]
    fn two_tier_is_bitexact_with_gpu_explicit() {
        for link in [Link::PciE, Link::NvLink] {
            for cyclic in [false, true] {
                for prefetch in [false, true] {
                    for slots in [2u8, 3] {
                        let opts = GpuOpts {
                            cyclic,
                            prefetch,
                            slots,
                        };
                        let calib = GpuCalib {
                            hbm_bytes: SMALL_HBM,
                            ..GpuCalib::default()
                        };
                        let boost = if link == Link::NvLink {
                            calib.nvlink_clock_boost
                        } else {
                            1.0
                        };
                        let mut legacy =
                            GpuExplicitEngine::new(calib.clone(), APP, link, opts).unwrap();
                        let mut tiered = TieredEngine::new(
                            gpu_two_tier(SMALL_HBM, link),
                            APP.gpu * boost,
                            calib.launch_s,
                            opts,
                        )
                        .unwrap();
                        let (ml, dl) = run_engine(&mut legacy, 3, true);
                        let (mt, dt) = run_engine(&mut tiered, 3, true);
                        let tag = format!("{link:?} cyclic={cyclic} prefetch={prefetch} slots={slots}");
                        assert_eq!(dl, dt, "numerics differ: {tag}");
                        assert_eq!(ml.elapsed_s, mt.elapsed_s, "clock differs: {tag}");
                        assert_eq!(ml.tiles, mt.tiles, "{tag}");
                        assert_eq!(ml.h2d_bytes, mt.h2d_bytes, "{tag}");
                        assert_eq!(ml.d2h_bytes, mt.d2h_bytes, "{tag}");
                        assert_eq!(ml.d2d_bytes, mt.d2d_bytes, "{tag}");
                        assert_eq!(ml.loop_time_s, mt.loop_time_s, "{tag}");
                        // the attribution ledger matches row for row
                        for (k, v) in &ml.per_resource {
                            let w = &mt.per_resource[k];
                            assert_eq!(v.busy_s, w.busy_s, "{tag} stream {k}");
                            assert_eq!(v.bytes, w.bytes, "{tag} stream {k}");
                        }
                    }
                }
            }
        }
    }

    fn three_tier(hbm: u64, host: u64) -> Topology {
        Topology::new(
            None,
            vec![
                Tier::new("hbm", Some(hbm), 509.7),
                Tier::new("host", Some(host), 11.0),
                Tier::new("nvme", None, 6.0),
            ],
            vec![LinkSpec::PCIE_HOST, LinkSpec::new(6.0, 20e-6)],
        )
        .unwrap()
    }

    #[test]
    fn three_tier_numerics_match_untiled_reference() {
        let (datasets, stencils, _, chain) = fixture(512);
        let mut store_ref = DataStore::new();
        datasets.iter().for_each(|d| store_ref.alloc(d));
        let mut reds_ref: Vec<Reduction> = vec![];
        let mut exec_ref = NativeExecutor::new();
        for l in &chain {
            exec_ref.run_loop(l, l.range, &datasets, &mut store_ref, &mut reds_ref);
        }
        let mut e =
            TieredEngine::new(three_tier(64 << 10, 512 << 10), APP.gpu, 7e-6, GpuOpts::default())
                .unwrap();
        let (m, bufs) = run_engine(&mut e, 1, true);
        for (d, buf) in datasets.iter().zip(&bufs) {
            assert_eq!(store_ref.buf(d.id), &buf[..], "dataset {}", d.name);
        }
        assert!(m.tiles >= 3, "expected several innermost tiles, got {}", m.tiles);
        // every boundary has its own named streams with real traffic
        for s in ["hbm:upload", "hbm:download", "host:upload", "host:download"] {
            assert!(m.per_resource.contains_key(s), "missing stream {s}");
        }
        assert!(m.per_resource["hbm:upload"].bytes > 0);
        assert!(m.per_resource["host:upload"].bytes > 0);
        assert_eq!(m.per_resource["hbm:upload"].bytes, m.h2d_bytes);
    }

    #[test]
    fn third_tier_costs_wall_clock() {
        let opts = GpuOpts {
            cyclic: true,
            prefetch: false,
            slots: 3,
        };
        let mut two =
            TieredEngine::new(gpu_two_tier(64 << 10, Link::PciE), APP.gpu, 7e-6, opts).unwrap();
        let mut three =
            TieredEngine::new(three_tier(64 << 10, 512 << 10), APP.gpu, 7e-6, opts).unwrap();
        let (m2, d2) = run_engine(&mut two, 2, true);
        let (m3, d3) = run_engine(&mut three, 2, true);
        assert_eq!(d2, d3, "an extra tier must not change numerics");
        assert!(
            m3.elapsed_s > m2.elapsed_s,
            "streaming through a third tier must cost time: {} !> {}",
            m3.elapsed_s,
            m2.elapsed_s
        );
    }

    #[test]
    fn single_tier_topology_computes_without_streaming() {
        let topo = Topology::new(None, vec![Tier::new("dram", None, 60.8)], vec![]).unwrap();
        let mut e = TieredEngine::new(topo, 50.0, 0.0, GpuOpts::default()).unwrap();
        let (m, _) = run_engine(&mut e, 1, false);
        assert_eq!(m.h2d_bytes + m.d2h_bytes + m.d2d_bytes, 0);
        assert!(m.elapsed_s > 0.0);
        assert_eq!(m.bound().name(), "compute");
        assert!(e.fits(u64::MAX));
    }

    #[test]
    fn fits_honours_the_home_tier() {
        let topo = Topology::new(
            None,
            vec![
                Tier::new("hbm", Some(1 << 20), 500.0),
                Tier::new("nvme", Some(1 << 30), 6.0),
            ],
            vec![LinkSpec::new(6.0, 20e-6)],
        )
        .unwrap();
        let e = TieredEngine::new(topo, APP.gpu, 7e-6, GpuOpts::default()).unwrap();
        assert!(e.fits(1 << 30));
        assert!(!e.fits((1 << 30) + 1));
    }

    #[test]
    fn reset_transient_clears_prefetch_credit() {
        let opts = GpuOpts::default();
        let run_pair = |reset: bool| -> f64 {
            let (datasets, stencils, mut store, chain) = fixture(512);
            let mut reds = vec![];
            let mut metrics = Metrics::new();
            let mut exec = NativeExecutor::new();
            let mut e =
                TieredEngine::new(gpu_two_tier(SMALL_HBM, Link::PciE), APP.gpu, 7e-6, opts)
                    .unwrap();
            for i in 0..2 {
                if reset && i == 1 {
                    e.reset_transient();
                }
                let mut world = World {
                    datasets: &datasets,
                    stencils: &stencils,
                    store: &mut store,
                    reds: &mut reds,
                    metrics: &mut metrics,
                    exec: &mut exec,
                };
                e.run_chain(&chain, &mut world, true);
            }
            metrics.elapsed_s
        };
        let warm = run_pair(false);
        let cold = run_pair(true);
        assert!(cold > warm, "reset must lose the prefetch overlap: {cold} !> {warm}");
    }

    #[test]
    fn codec_identity_is_bitexact_and_real_codec_cuts_wire_bytes() {
        use crate::codec::CodecSpec;
        let opts = GpuOpts::default();
        let base = gpu_two_tier(SMALL_HBM, Link::PciE);
        let with = |c: CodecSpec| base.clone().with_codecs(vec![Some(c)]).unwrap();
        let mut plain_e = TieredEngine::new(base.clone(), APP.gpu, 7e-6, opts).unwrap();
        let (mp, dp) = run_engine(&mut plain_e, 2, true);

        // ratio 1.0: clocks, bytes and ledger all bit-identical
        let mut id_e = TieredEngine::new(with(CodecSpec::new(1.0)), APP.gpu, 7e-6, opts).unwrap();
        let (mi, di) = run_engine(&mut id_e, 2, true);
        assert_eq!(dp, di);
        assert_eq!(mp.elapsed_s, mi.elapsed_s);
        assert_eq!(mp.h2d_bytes, mi.h2d_bytes);
        assert_eq!(mi.codec_bytes_saved, 0);
        assert!(!mi.per_resource.contains_key("codec"), "identity codec emits no stream");
        for (k, v) in &mp.per_resource {
            let w = &mi.per_resource[k];
            assert_eq!(v.busy_s, w.busy_s, "stream {k}");
            assert_eq!(v.bytes, w.bytes, "stream {k}");
        }

        // a real codec: same numerics, fewer wire bytes, its own stream
        let mut z_e = TieredEngine::new(with(CodecSpec::ZFP), APP.gpu, 7e-6, opts).unwrap();
        let (mz, dz) = run_engine(&mut z_e, 2, true);
        assert_eq!(dp, dz, "codec is a timeline model — numerics untouched");
        assert!(mz.codec_bytes_saved > 0);
        assert_eq!(mz.h2d_bytes, mp.h2d_bytes, "h2d ledger keeps logical bytes");
        assert!(
            mz.per_resource["upload"].bytes < mp.per_resource["upload"].bytes,
            "the upload stream ships wire bytes"
        );
        assert!(mz.per_resource["codec"].busy_s > 0.0);
        assert!(
            mz.elapsed_s < mp.elapsed_s,
            "this transfer-bound cell must speed up: {} !< {}",
            mz.elapsed_s,
            mp.elapsed_s
        );
    }

    #[test]
    fn tuner_plan_seam_works_at_the_innermost_level() {
        let run_src = |src: PlanSource| {
            let mut e =
                TieredEngine::new(gpu_two_tier(SMALL_HBM, Link::PciE), APP.gpu, 7e-6, GpuOpts::default())
                    .unwrap();
            e.plans[0] = src;
            run_engine(&mut e, 1, true).0
        };
        let auto = run_src(PlanSource::Auto);
        let over = run_src(PlanSource::Fixed(1));
        assert_eq!(
            over.tiles, auto.tiles,
            "an over-capacity fixed count must fall back to auto sizing"
        );
        let ok = run_src(PlanSource::Fixed(auto.tiles as usize + 2));
        assert_eq!(ok.tiles, auto.tiles + 2, "feasible fixed counts are honoured");
    }
}
