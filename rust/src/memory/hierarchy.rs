//! Calibration constants for the modelled memory hierarchies.
//!
//! Every number here is taken from the paper's own measurements (§5.2,
//! §5.3) — the simulator's *inputs* are the paper's microbenchmark /
//! baseline numbers; its *outputs* (scaling curves, tiling gains,
//! crossovers) emerge from the modelled system behaviour and are compared
//! against the paper's figures in EXPERIMENTS.md.


// The shared unit constants live with the other calibration helpers in
// [`super::calib_util`]; re-exported here for compatibility.
pub use super::calib_util::{GB, GIB};

/// Knights Landing (Xeon Phi x200 7210) calibration, §5.2.
#[derive(Debug, Clone)]
pub struct KnlCalib {
    /// MCDRAM capacity.
    pub mcdram_bytes: u64,
    /// Flat-mode MCDRAM STREAM bandwidth (dynamic allocation), GB/s.
    pub bw_mcdram_flat: f64,
    /// Cache-mode STREAM bandwidth, GB/s.
    pub bw_mcdram_cache: f64,
    /// DDR4 STREAM bandwidth, GB/s.
    pub bw_ddr4: f64,
    /// Granule of the direct-mapped MCDRAM-cache simulator, bytes.
    /// (Real MCDRAM cache is direct-mapped at 64 B lines; we simulate at
    /// coarser granules since stencil sweeps stream contiguous slabs.)
    pub cache_granule: u64,
    /// Per-exchange MPI halo latency, seconds (4 ranks on one chip).
    pub halo_latency_s: f64,
}

impl Default for KnlCalib {
    fn default() -> Self {
        KnlCalib {
            mcdram_bytes: 16 * GIB,
            bw_mcdram_flat: 314.0,
            bw_mcdram_cache: 291.0,
            bw_ddr4: 60.8,
            cache_granule: 1 << 20,
            halo_latency_s: 8e-6,
        }
    }
}

/// Interconnect between host and device memory.
///
/// A thin shim over [`crate::topology::LinkSpec`]: the two calibrated
/// host links are [`LinkSpec::PCIE_HOST`] and [`LinkSpec::NVLINK_HOST`]
/// — this enum survives as the compact spec-token form (`pcie` /
/// `nvlink`) the legacy `Platform` variants carry.
///
/// [`LinkSpec::PCIE_HOST`]: crate::topology::LinkSpec::PCIE_HOST
/// [`LinkSpec::NVLINK_HOST`]: crate::topology::LinkSpec::NVLINK_HOST
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Link {
    /// PCIe gen3 x16 — the paper measures ~11 GB/s achieved throughput.
    PciE,
    /// NVLink 1.0 to a Power8 — ~30 GB/s achieved.
    NvLink,
}

impl Link {
    /// The unified link description this variant stands for.
    pub fn spec(self) -> crate::topology::LinkSpec {
        match self {
            Link::PciE => crate::topology::LinkSpec::PCIE_HOST,
            Link::NvLink => crate::topology::LinkSpec::NVLINK_HOST,
        }
    }

    /// Achieved bandwidth per direction, GB/s (paper §5.3).
    #[deprecated(since = "0.4.0", note = "use Link::spec().bw_gbs (topology::LinkSpec)")]
    pub fn bw_gbs(self) -> f64 {
        self.spec().bw_gbs
    }

    /// Per-transfer launch latency, seconds.
    #[deprecated(since = "0.4.0", note = "use Link::spec().latency_s (topology::LinkSpec)")]
    pub fn latency_s(self) -> f64 {
        self.spec().latency_s
    }

    /// Time to move `bytes` over the link.
    #[deprecated(since = "0.4.0", note = "use Link::spec().time_s (topology::LinkSpec)")]
    pub fn time_s(self, bytes: u64) -> f64 {
        self.spec().time_s(bytes)
    }

    pub fn name(self) -> &'static str {
        match self {
            Link::PciE => "PCIe",
            Link::NvLink => "NVLink",
        }
    }
}

/// P100 calibration, §5.3.
#[derive(Debug, Clone)]
pub struct GpuCalib {
    /// HBM2 capacity.
    pub hbm_bytes: u64,
    /// Device-to-device streaming copy bandwidth, GB/s (measured 509.7).
    pub bw_device: f64,
    /// Kernel launch overhead, seconds.
    pub launch_s: f64,
    /// NVLink cards clock slightly higher (§5.3: "NVLink performance is
    /// slightly higher due to higher graphics clock speeds").
    pub nvlink_clock_boost: f64,
}

impl Default for GpuCalib {
    fn default() -> Self {
        GpuCalib {
            hbm_bytes: 16 * GIB,
            bw_device: 509.7,
            launch_s: 7e-6,
            nvlink_clock_boost: 1.03,
        }
    }
}

/// Unified-memory calibration, §5.4.
#[derive(Debug, Clone)]
pub struct UnifiedCalib {
    /// Residency granularity (Pascal tracks 2 MiB VA blocks).
    pub page_bytes: u64,
    /// On-demand migration granule: faults move small groups of 4 KiB
    /// pages (~64 KiB) — this is why fault throughput is latency-bound
    /// and *identical* on PCIe and NVLink (§5.4).
    pub fault_chunk_bytes: u64,
    /// Service latency of one fault-group migration, seconds.
    pub fault_latency_s: f64,
    /// Fraction of link bandwidth `cudaMemPrefetchAsync` achieves while
    /// *not* oversubscribed.
    pub prefetch_eff: f64,
    /// Fraction once the resident set exceeds device memory ("the
    /// performance of prefetches drops significantly once we start
    /// oversubscribing", §5.4).
    pub prefetch_eff_oversub: f64,
    /// Fraction of prefetch time that overlaps compute (driver-side CPU
    /// work limits overlap, §5.4).
    pub prefetch_overlap: f64,
}

impl Default for UnifiedCalib {
    fn default() -> Self {
        UnifiedCalib {
            page_bytes: 2 << 20,
            fault_chunk_bytes: 64 << 10,
            fault_latency_s: 25e-6,
            prefetch_eff: 0.9,
            prefetch_eff_oversub: 0.45,
            prefetch_overlap: 0.6,
        }
    }
}

/// Application-level calibrated baselines (GB/s) — the paper's measured
/// flat-mode / in-memory numbers (§5.2, §5.3). These feed the per-loop
/// time model; everything *else* (scaling, tiling effects) is emergent.
#[derive(Debug, Clone, Copy)]
pub struct AppCalib {
    /// Average bandwidth in flat-DDR4 mode.
    pub knl_ddr4: f64,
    /// Average bandwidth in flat-MCDRAM mode.
    pub knl_mcdram: f64,
    /// Average bandwidth on the P100 with data resident.
    pub gpu: f64,
}

impl AppCalib {
    pub const CLOVERLEAF_2D: AppCalib = AppCalib {
        knl_ddr4: 50.0,
        knl_mcdram: 240.0,
        gpu: 470.0,
    };
    pub const CLOVERLEAF_3D: AppCalib = AppCalib {
        knl_ddr4: 50.0,
        knl_mcdram: 200.0,
        gpu: 380.0,
    };
    pub const OPENSBLI: AppCalib = AppCalib {
        knl_ddr4: 30.0,
        knl_mcdram: 83.0,
        gpu: 170.0,
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_time_includes_latency() {
        let t = Link::PciE.spec().time_s(11_000_000_000);
        assert!((t - (1.0 + 10e-6)).abs() < 1e-9);
        assert_eq!(Link::PciE.spec().time_s(0), 0.0);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_link_shims_delegate_to_linkspec() {
        assert_eq!(Link::PciE.bw_gbs(), Link::PciE.spec().bw_gbs);
        assert_eq!(Link::NvLink.latency_s(), Link::NvLink.spec().latency_s);
        assert_eq!(Link::NvLink.time_s(1 << 20), Link::NvLink.spec().time_s(1 << 20));
    }

    #[test]
    fn defaults_match_paper() {
        let k = KnlCalib::default();
        assert_eq!(k.mcdram_bytes, 16 * GIB);
        assert!((k.bw_ddr4 - 60.8).abs() < 1e-12);
        let g = GpuCalib::default();
        assert!((g.bw_device - 509.7).abs() < 1e-12);
        assert!(Link::NvLink.spec().bw_gbs > Link::PciE.spec().bw_gbs);
    }
}
