//! Memory-hierarchy models and engines.
//!
//! Four engines reproduce the paper's four execution environments:
//!
//! | Engine | Paper configuration |
//! |---|---|
//! | [`PlainEngine`] | KNL flat DDR4 / flat MCDRAM, GPU in-memory baseline |
//! | [`KnlEngine`] | KNL MCDRAM cache mode, with/without tiling (§5.2) |
//! | [`GpuExplicitEngine`] | explicit 3-slot streaming, Algorithm 1 (§4, §5.3) |
//! | [`UnifiedEngine`] | CUDA unified memory ± tiling ± prefetch (§5.4) |
//! | [`TieredEngine`] | Algorithm 1 recursively over any declarative [`crate::topology::Topology`] — two-tier GPU stacks reproduce [`GpuExplicitEngine`] bit-exactly, deeper stacks stream past host DRAM |
//!
//! All are calibrated from the paper's own measured microbenchmarks
//! ([`hierarchy`]); everything else is emergent behaviour of the
//! simulated system.

pub mod cache_sim;
pub mod calib_util;
pub mod gpu_explicit;
pub mod halo;
pub mod hierarchy;
pub mod knl;
pub mod plain;
pub mod tiered;
pub mod unified;

pub use cache_sim::{AccessResult, AddressMap, CacheSim};
pub use gpu_explicit::{GpuExplicitEngine, GpuOpts};
pub use halo::HaloModel;
pub use hierarchy::{AppCalib, GpuCalib, KnlCalib, Link, UnifiedCalib};
pub use knl::KnlEngine;
pub use plain::PlainEngine;
pub use tiered::TieredEngine;
pub use unified::UnifiedEngine;
