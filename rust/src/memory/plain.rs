//! The "flat" engine: all data resident in one memory, loops execute in
//! chain order at a calibrated bandwidth. Models flat-DDR4 and
//! flat-MCDRAM on the KNL and the in-memory GPU baseline (≤ 16 GB).

use super::calib_util::{chain_bw_norm, elem_bytes};
use super::halo::HaloModel;
use crate::exec::timeline::{EventKind, StreamClass, Timeline};
use crate::exec::{Engine, World};
use crate::ops::LoopInst;

/// Flat-memory engine with a calibrated per-app bandwidth.
#[derive(Debug, Clone)]
pub struct PlainEngine {
    /// Calibrated app-level average bandwidth, GB/s.
    pub bw_gbs: f64,
    /// Capacity of the memory all data must fit in (`None` = unbounded,
    /// e.g. DDR4). Flat-MCDRAM and the GPU baseline refuse larger
    /// problems — the paper reports segfaults/OOM there.
    pub mem_limit: Option<u64>,
    /// Per-loop launch/dispatch overhead, seconds (GPU kernel launch).
    pub launch_s: f64,
    /// Optional MPI halo-exchange model (KNL runs use 4 ranks).
    pub halo: Option<HaloModel>,
    /// Label for reports.
    pub label: String,
}

impl PlainEngine {
    pub fn knl_flat_ddr4(bw_gbs: f64) -> Self {
        PlainEngine {
            bw_gbs,
            mem_limit: None,
            launch_s: 0.0,
            halo: Some(HaloModel::knl()),
            label: "KNL flat DDR4".into(),
        }
    }

    pub fn knl_flat_mcdram(bw_gbs: f64, mcdram_bytes: u64) -> Self {
        PlainEngine {
            bw_gbs,
            mem_limit: Some(mcdram_bytes),
            launch_s: 0.0,
            halo: Some(HaloModel::knl()),
            label: "KNL flat MCDRAM".into(),
        }
    }

    pub fn gpu_baseline(bw_gbs: f64, hbm_bytes: u64, launch_s: f64) -> Self {
        PlainEngine {
            bw_gbs,
            mem_limit: Some(hbm_bytes),
            launch_s,
            halo: None,
            label: "GPU baseline (resident)".into(),
        }
    }

    fn loop_time(&self, l: &LoopInst, bytes: u64, norm: f64) -> f64 {
        bytes as f64 / (self.bw_gbs * l.bw_efficiency * norm * 1e9) + self.launch_s
    }
}

impl Engine for PlainEngine {
    fn run_chain(&mut self, chain: &[LoopInst], world: &mut World<'_>, _cyclic_phase: bool) {
        world.metrics.chains += 1;
        let sp = crate::obs::span("plain");
        sp.field("loops", chain.len());
        let tile_dim = crate::tiling::plan::pick_tile_dim(chain);
        let norm = chain_bw_norm(world, chain);
        // One compute stream; per-loop MPI halo exchanges (§5.2) run on a
        // `halo` resource that serialises against it (flat execution has
        // no overlap to model — the event graph is a single chain).
        let mut tl = Timeline::for_world(world);
        let rc = tl.resource("compute", StreamClass::Compute);
        let rh = self
            .halo
            .as_ref()
            .map(|_| tl.resource("halo", StreamClass::Exchange));
        for l in chain {
            world
                .exec
                .run_loop(l, l.range, world.datasets, world.store, world.reds);
            let bytes = l.bytes_touched(elem_bytes(world, l));
            let t = self.loop_time(l, bytes, norm);
            world.metrics.record_loop(&l.name, bytes, t);
            tl.push(rc, EventKind::Compute, &l.name, t, bytes);
            if let (Some(h), Some(rh)) = (&self.halo, rh) {
                // Untiled execution exchanges halos per loop (§5.2).
                let (ht, n) = h.per_loop_cost(l, world.datasets, world.stencils, tile_dim);
                world.metrics.halo_time_s += ht;
                world.metrics.halo_exchanges += n;
                if n > 0 {
                    world.metrics.obs.record("halo_exchange_s", ht);
                    let at = tl.cursor(rc);
                    let end = tl.push_at(rh, EventKind::Halo, &l.name, at, ht, 0);
                    tl.wait_until(rc, end);
                }
            }
        }
        world.metrics.absorb_timeline(tl);
    }

    fn describe(&self) -> String {
        format!("{} @ {:.1} GB/s", self.label, self.bw_gbs)
    }

    fn fits(&self, problem_bytes: u64) -> bool {
        self.mem_limit.map_or(true, |m| problem_bytes <= m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{Metrics, NativeExecutor};
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::shapes;
    use crate::ops::*;

    fn world_fixture() -> (Vec<Dataset>, Vec<Stencil>, DataStore) {
        let d = Dataset {
            id: DatasetId(0),
            block: BlockId(0),
            name: "d".into(),
            size: [64, 64, 1],
            halo_lo: [1, 1, 0],
            halo_hi: [1, 1, 0],
            elem_bytes: 8,
        };
        let mut store = DataStore::new();
        store.alloc(&d);
        let stencils = vec![Stencil {
            id: StencilId(0),
            name: "pt".into(),
            points: shapes::point(),
        }];
        (vec![d], stencils, store)
    }

    #[test]
    fn records_time_at_calibrated_bw() {
        let (datasets, stencils, mut store) = world_fixture();
        let mut reds = vec![];
        let mut metrics = Metrics::new();
        let mut exec = NativeExecutor::new();
        let mut world = World {
            datasets: &datasets,
            stencils: &stencils,
            store: &mut store,
            reds: &mut reds,
            metrics: &mut metrics,
            exec: &mut exec,
        };
        let chain = vec![LoopInst {
            name: "w".into(),
            block: BlockId(0),
            range: [(0, 64), (0, 64), (0, 1)],
            args: vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)],
            kernel: kernel(|c| c.w(0, 0, 0, 1.0)),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        }];
        let mut e = PlainEngine {
            bw_gbs: 100.0,
            mem_limit: None,
            launch_s: 0.0,
            halo: None,
            label: "t".into(),
        };
        e.run_chain(&chain, &mut world, false);
        let bytes = 64 * 64 * 8;
        assert_eq!(metrics.loop_bytes, bytes);
        assert!((metrics.loop_time_s - bytes as f64 / 100e9).abs() < 1e-15);
        assert!((metrics.average_bandwidth_gbs() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn fits_respects_limit() {
        let e = PlainEngine::knl_flat_mcdram(240.0, 1000);
        assert!(e.fits(1000));
        assert!(!e.fits(1001));
        let d = PlainEngine::knl_flat_ddr4(50.0);
        assert!(d.fits(u64::MAX));
    }
}
