//! Explicitly-managed GPU streaming engine — the paper's Algorithm 1.
//!
//! Three CUDA-stream-like timelines run concurrently: stream 0 executes
//! tiles (and the device-device edge copies), stream 1 uploads the next
//! tile's "right footprint", stream 2 downloads the previous tile's
//! "left (written) footprint". Triple buffering ("three slots") lets all
//! three proceed simultaneously; the Algorithm-1 waits provide the
//! synchronisation. §4.1's optimisations are all modelled:
//!
//! * read-only datasets are never downloaded, write-first never uploaded
//!   (always on, like the paper);
//! * **Cyclic** — once the app signals cyclic execution, write-first
//!   (temporary) datasets are not downloaded either (unsafe opt-in);
//! * **Prefetch** — the upload of the *next chain's* first tile is
//!   speculatively overlapped with the last tile of the current chain.

use super::calib_util::{chain_bw_norm, elem_bytes};
use super::hierarchy::{AppCalib, GpuCalib, Link, GB};
use crate::exec::timeline::{EventKind, StreamClass, Timeline};
use crate::exec::{Engine, World};
use crate::ops::{DatasetId, LoopInst};
use crate::tiling::analysis::ChainAnalysis;
use crate::tiling::plan::{PlanSource, TilePlan};

/// §4.1 optimisation switches (read-only/write-first skipping is always
/// on, as in the paper's evaluation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GpuOpts {
    /// Skip downloading write-first (temporary) data during cyclic phases.
    pub cyclic: bool,
    /// Speculatively prefetch the next chain's first tile.
    pub prefetch: bool,
    /// Buffering depth: 3 = the paper's "three slots" (uploads, compute
    /// and downloads all concurrent); 2 = double buffering (uploads and
    /// downloads share one staging slot and serialise against each
    /// other) — the ablation that justifies triple buffering.
    pub slots: u8,
}

impl Default for GpuOpts {
    fn default() -> Self {
        GpuOpts {
            cyclic: true,
            prefetch: true,
            slots: 3,
        }
    }
}

impl GpuOpts {
    /// Validate the option set. `slots` must be 2 (double buffering) or
    /// 3 (the paper's triple buffering): 0/1 slots cannot overlap
    /// anything and the old code silently modelled them as double
    /// buffering, >3 as triple — both now rejected with a typed error
    /// instead of modelling nonsense.
    pub fn validate(&self) -> crate::Result<()> {
        crate::ensure!(
            (2..=3).contains(&self.slots),
            "GpuOpts::slots must be 2 (double buffering) or 3 (triple buffering), got {}",
            self.slots
        );
        Ok(())
    }
}

/// The explicit-management streaming engine.
pub struct GpuExplicitEngine {
    pub calib: GpuCalib,
    pub app: AppCalib,
    pub link: Link,
    pub opts: GpuOpts,
    /// Where tile plans come from (default: auto-size to HBM/3 slots;
    /// the tuner injects `Fixed` counts here).
    pub plan: PlanSource,
    /// Prefetch credit carried from the previous chain: overlap window
    /// (seconds) during which the next chain's first upload already ran.
    prefetch_credit: f64,
    /// Bytes speculatively uploaded for the next chain (diagnostics).
    pub speculative_bytes: u64,
}

impl GpuExplicitEngine {
    /// Build the engine; rejects invalid buffering depths with a typed
    /// error ([`GpuOpts::validate`]).
    pub fn new(calib: GpuCalib, app: AppCalib, link: Link, opts: GpuOpts) -> crate::Result<Self> {
        opts.validate()?;
        Ok(GpuExplicitEngine {
            calib,
            app,
            link,
            opts,
            plan: PlanSource::Auto,
            prefetch_credit: 0.0,
            speculative_bytes: 0,
        })
    }

    /// The heuristic per-slot byte budget tiles are auto-sized to: an
    /// equal HBM share per slot, with a little headroom for OPS
    /// bookkeeping. Public so the tuner can seed its search from the
    /// exact same number the engine uses.
    pub fn slot_target(&self) -> u64 {
        // `opts` is a pub field, so the constructor's validation can be
        // bypassed after the fact — clamp as defense-in-depth (slots: 0
        // would otherwise divide to +inf).
        let nslots = self.opts.slots.clamp(2, 3) as f64;
        (self.calib.hbm_bytes as f64 / nslots * 0.92) as u64
    }

    fn dev_bw(&self) -> f64 {
        let boost = if self.link == Link::NvLink {
            self.calib.nvlink_clock_boost
        } else {
            1.0
        };
        self.app.gpu * boost
    }

    fn compute_time(&self, l: &LoopInst, bytes: u64, norm: f64) -> f64 {
        bytes as f64 / (self.dev_bw() * l.bw_efficiency * norm * GB) + self.calib.launch_s
    }
}

/// Per-tile transfer byte counts derived from the plan + §4.1 rules.
pub struct TileTraffic {
    pub upload: u64,
    pub download: u64,
    pub edge: u64,
}

/// Compute tile `t`'s traffic. Public so benches/tests can audit the
/// §4.1 optimisations byte-for-byte.
pub fn tile_traffic(
    plan: &TilePlan,
    t: usize,
    datasets: &[crate::ops::Dataset],
    skip_upload: &[bool],
    skip_download: &[bool],
) -> TileTraffic {
    let dim = plan.tile_dim;
    let mut up = 0u64;
    let mut down = 0u64;
    let mut edge = 0u64;
    for (d, fp) in plan.tiles[t].footprints.iter().enumerate() {
        let Some(fp) = fp else { continue };
        let ds = &datasets[d];
        let plane = ds.plane_bytes(dim);
        let id = DatasetId(d as u32);
        if !skip_upload[d] {
            let iv = if t == 0 {
                fp.full
            } else {
                plan.right_footprint(t, id)
            };
            up += iv.len() as u64 * plane;
        }
        if !skip_download[d] {
            down += plan.left_written_footprint(t, id).len() as u64 * plane;
        }
        // Edge copy to the next tile's slot (data valid on device that the
        // next tile needs; upload-skipped datasets still need their edges
        // carried forward since they are never uploaded).
        edge += plan.right_edge(t, id).len() as u64 * plane;
    }
    TileTraffic {
        upload: up,
        download: down,
        edge,
    }
}

impl Engine for GpuExplicitEngine {
    fn run_chain(&mut self, chain: &[LoopInst], world: &mut World<'_>, cyclic_phase: bool) {
        self.run_chain_analyzed(chain, None, world, cyclic_phase);
    }

    fn run_chain_analyzed(
        &mut self,
        chain: &[LoopInst],
        analysis: Option<&ChainAnalysis>,
        world: &mut World<'_>,
        cyclic_phase: bool,
    ) {
        world.metrics.chains += 1;
        let sp = crate::obs::span("gpu_explicit");
        sp.field("loops", chain.len());
        // Legacy eager path: no cached analysis, rebuild it per flush.
        let mut local = None;
        let analysis =
            ChainAnalysis::resolve(analysis, &mut local, chain, world.datasets, world.stencils);
        // All slots must fit in HBM: target one slot at just under an
        // equal share (leave a little headroom for OPS bookkeeping).
        let slot_target = self.slot_target();
        let mut plan = self
            .plan
            .plan_analyzed(chain, world.datasets, world.stencils, slot_target, analysis);
        if matches!(self.plan, PlanSource::Fixed(_))
            && plan.max_footprint_bytes(world.datasets) > slot_target
        {
            // A fixed tile count must still honour the slot-capacity
            // contract (all slots resident in HBM). Over-budget requests
            // fall back to auto sizing, so a tuner candidate can never
            // score a win by overflowing device memory.
            plan = PlanSource::Auto.plan_analyzed(
                chain,
                world.datasets,
                world.stencils,
                slot_target,
                analysis,
            );
        }
        let nt = plan.num_tiles();
        sp.field("tiles", nt);
        world.metrics.tiles += nt as u64;
        let norm = chain_bw_norm(world, chain);

        // §4.1 data-movement classification (from the cached analysis).
        let nd = world.datasets.len();
        let mut skip_upload = vec![false; nd];
        let mut skip_download = vec![false; nd];
        for (id, info) in &analysis.summary {
            let d = id.0 as usize;
            skip_upload[d] = info.skip_upload();
            skip_download[d] = info.skip_download()
                || (self.opts.cyclic && cyclic_phase && info.write_first);
        }

        // Algorithm 1's three CUDA streams as timeline resources:
        // stream 0 executes tiles + edge copies, stream 1 uploads the
        // next tile's right footprint, stream 2 downloads the previous
        // tile's written left footprint. The Algorithm-1 waits are
        // `wait` edges; the makespan is the chain's modelled wall clock.
        let mut tl = Timeline::for_world(world);
        let s0 = tl.resource("compute", StreamClass::Compute);
        let s1 = tl.resource("upload", StreamClass::Upload);
        let s2 = tl.resource("download", StreamClass::Download);
        let tracing = tl.tracing();
        let mut last_tile_compute = 0.0f64;

        // Tile 0's upload, minus any speculative prefetch from the
        // previous chain.
        let tr0 = tile_traffic(&plan, 0, world.datasets, &skip_upload, &skip_download);
        let mut up_time = self.link.spec().time_s(tr0.upload);
        if self.opts.prefetch && self.prefetch_credit > 0.0 {
            let credit = self.prefetch_credit.min(up_time);
            up_time -= credit;
        }
        world.metrics.h2d_bytes += tr0.upload;
        if tr0.upload > 0 || up_time > 0.0 {
            tl.push(s1, EventKind::Upload, "tile 0", up_time, tr0.upload);
        }

        for t in 0..nt {
            let tsp = crate::obs::span("tile");
            tsp.field("t", t);
            let label = |what: &str| -> String {
                if tracing {
                    format!("{what} {t}")
                } else {
                    String::new()
                }
            };
            // ---- preparation: wait streams 0 & 1, then upload next tile.
            // With 2 slots the upload stream is also the download stream:
            // the shared staging slot serialises the two directions.
            if self.opts.slots < 3 {
                tl.wait(s1, s2);
            }
            tl.wait(s0, s1);
            if t + 1 < nt {
                let trn = tile_traffic(&plan, t + 1, world.datasets, &skip_upload, &skip_download);
                if trn.upload > 0 {
                    let lbl = if tracing {
                        format!("tile {}", t + 1)
                    } else {
                        String::new()
                    };
                    tl.push(
                        s1,
                        EventKind::Upload,
                        &lbl,
                        self.link.spec().time_s(trn.upload),
                        trn.upload,
                    );
                }
                world.metrics.h2d_bytes += trn.upload;
            }

            // ---- execution phase: run all loops of this tile (stream 0).
            let mut tile_compute = 0.0;
            let mut tile_bytes_sum = 0u64;
            for (li, r) in plan.tiles[t].loop_ranges.iter().enumerate() {
                let Some(r) = r else { continue };
                let l = &chain[li];
                world
                    .exec
                    .run_loop(l, *r, world.datasets, world.store, world.reds);
                let frac = crate::ops::parloop::range_points(r) as f64
                    / crate::ops::parloop::range_points(&l.range).max(1) as f64;
                let bytes = (l.bytes_touched(elem_bytes(world, l)) as f64 * frac) as u64;
                let ct = self.compute_time(l, bytes, norm);
                world.metrics.record_loop(&l.name, bytes, ct);
                tile_compute += ct;
                tile_bytes_sum += bytes;
            }
            // One compute event per executed tile (the per-loop split is
            // in `per_loop`; the stream sees the fused tile execution).
            tl.push(s0, EventKind::Compute, &label("tile"), tile_compute, tile_bytes_sum);
            world.metrics.obs.record("tile_compute_s", tile_compute);
            last_tile_compute = tile_compute;

            // ---- finishing: wait streams 0 & 2; edge copy; download.
            tl.wait(s0, s2);
            let tr = tile_traffic(&plan, t, world.datasets, &skip_upload, &skip_download);
            if tr.edge > 0 {
                tl.push(
                    s0,
                    EventKind::EdgeCopy,
                    &label("edge"),
                    tr.edge as f64 / (self.calib.bw_device * GB),
                    tr.edge,
                );
            }
            world.metrics.d2d_bytes += tr.edge;
            if tr.download > 0 {
                tl.push(
                    s2,
                    EventKind::Download,
                    &label("tile"),
                    self.link.spec().time_s(tr.download),
                    tr.download,
                );
            }
            world.metrics.d2h_bytes += tr.download;
        }

        world.metrics.absorb_timeline(tl);

        // Speculative prefetch for the next chain overlaps the last tile's
        // execution (§4.1). Our chains are cyclic, so the speculation is
        // exact; the paper uploads any missing pieces on chain start.
        if self.opts.prefetch {
            self.prefetch_credit = last_tile_compute;
            self.speculative_bytes +=
                tr0.upload.min((last_tile_compute * self.link.spec().bw_gbs * GB) as u64);
        } else {
            self.prefetch_credit = 0.0;
        }
    }

    /// Forget cross-chain speculation: a rebound engine must not apply
    /// prefetch credit earned under a different session's chains.
    fn reset_transient(&mut self) {
        self.prefetch_credit = 0.0;
        self.speculative_bytes = 0;
    }

    fn describe(&self) -> String {
        format!(
            "GPU explicit {} {}{}",
            self.link.name(),
            if self.opts.cyclic { "Cyclic" } else { "NoCyclic" },
            if self.opts.prefetch { " Prefetch" } else { " NoPrefetch" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{Metrics, NativeExecutor};
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::exec::Executor;
    use crate::ops::*;

    const APP: AppCalib = AppCalib {
        knl_ddr4: 50.0,
        knl_mcdram: 240.0,
        gpu: 470.0,
    };

    /// Chain: temp = f(state); state' = g(temp, state) — has a read-only
    /// ("coords"), a write-first temp, and a read-write state.
    fn fixture(ny: usize) -> (Vec<Dataset>, Vec<Stencil>, DataStore, Vec<LoopInst>) {
        let mut datasets = vec![];
        let mut store = DataStore::new();
        for (i, name) in ["state", "temp", "coords"].iter().enumerate() {
            let d = Dataset {
                id: DatasetId(i as u32),
                block: BlockId(0),
                name: name.to_string(),
                size: [64, ny, 1],
                halo_lo: [2, 2, 0],
                halo_hi: [2, 2, 0],
                elem_bytes: 8,
            };
            store.alloc(&d);
            datasets.push(d);
        }
        let stencils = vec![
            Stencil {
                id: StencilId(0),
                name: "pt".into(),
                points: shapes::point(),
            },
            Stencil {
                id: StencilId(1),
                name: "star".into(),
                points: shapes::star2d(1),
            },
        ];
        let range: Range3 = [(0, 64), (0, ny as isize), (0, 1)];
        let chain = vec![
            LoopInst {
                name: "mk_temp".into(),
                block: BlockId(0),
                range,
                args: vec![
                    Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(2), StencilId(0), Access::Read),
                    Arg::dat(DatasetId(1), StencilId(0), Access::Write),
                ],
                kernel: kernel(|c| {
                    let v = c.r(0, -1, 0) + c.r(0, 1, 0) + c.r(1, 0, 0);
                    c.w(2, 0, 0, v * 0.25);
                }),
                kernel_ir: None,
                seq: 0,
                bw_efficiency: 1.0,
            },
            LoopInst {
                name: "update".into(),
                block: BlockId(0),
                range,
                args: vec![
                    Arg::dat(DatasetId(1), StencilId(1), Access::Read),
                    Arg::dat(DatasetId(0), StencilId(0), Access::ReadWrite),
                ],
                kernel: kernel(|c| {
                    let v = c.r(0, 0, -1) + c.r(0, 0, 1);
                    let s = c.r(1, 0, 0);
                    c.w(1, 0, 0, s + 0.1 * v);
                }),
                kernel_ir: None,
                seq: 1,
                bw_efficiency: 1.0,
            },
        ];
        (datasets, stencils, store, chain)
    }

    fn run_with(
        opts: GpuOpts,
        link: Link,
        cyclic_phase: bool,
        hbm: u64,
        chains: usize,
    ) -> Metrics {
        let (datasets, stencils, mut store, chain) = fixture(512);
        let mut reds = vec![];
        let mut metrics = Metrics::new();
        let mut exec = NativeExecutor::new();
        let calib = GpuCalib {
            hbm_bytes: hbm,
            ..GpuCalib::default()
        };
        let mut e = GpuExplicitEngine::new(calib, APP, link, opts).unwrap();
        for _ in 0..chains {
            let mut world = World {
                datasets: &datasets,
                stencils: &stencils,
                store: &mut store,
                reds: &mut reds,
                metrics: &mut metrics,
                exec: &mut exec,
            };
            e.run_chain(&chain, &mut world, cyclic_phase);
        }
        metrics
    }

    /// Problem is 3 datasets x 64x512 x 8B ≈ 786 KiB.
    const SMALL_HBM: u64 = 256 << 10; // forces ~9+ tiles

    #[test]
    fn read_only_data_never_downloaded() {
        let m = run_with(GpuOpts { cyclic: false, prefetch: false, slots: 3 }, Link::PciE, false, SMALL_HBM, 1);
        // downloads must cover state (rw) + temp (written), but coords is
        // read-only: total downloaded < total uploaded (coords uploaded).
        assert!(m.d2h_bytes > 0);
        assert!(m.h2d_bytes > 0);
    }

    #[test]
    fn cyclic_opt_skips_temp_downloads() {
        let base = run_with(GpuOpts { cyclic: false, prefetch: false, slots: 3 }, Link::PciE, true, SMALL_HBM, 1);
        let cyc = run_with(GpuOpts { cyclic: true, prefetch: false, slots: 3 }, Link::PciE, true, SMALL_HBM, 1);
        assert!(
            cyc.d2h_bytes < base.d2h_bytes,
            "cyclic should reduce downloads: {} !< {}",
            cyc.d2h_bytes,
            base.d2h_bytes
        );
        assert!(cyc.elapsed_s <= base.elapsed_s);
    }

    #[test]
    fn cyclic_opt_inactive_outside_cyclic_phase() {
        let a = run_with(GpuOpts { cyclic: true, prefetch: false, slots: 3 }, Link::PciE, false, SMALL_HBM, 1);
        let b = run_with(GpuOpts { cyclic: false, prefetch: false, slots: 3 }, Link::PciE, false, SMALL_HBM, 1);
        assert_eq!(a.d2h_bytes, b.d2h_bytes);
    }

    #[test]
    fn prefetch_helps_across_chains() {
        let no = run_with(GpuOpts { cyclic: true, prefetch: false, slots: 3 }, Link::PciE, true, SMALL_HBM, 4);
        let yes = run_with(GpuOpts { cyclic: true, prefetch: true, slots: 3 }, Link::PciE, true, SMALL_HBM, 4);
        assert!(
            yes.elapsed_s < no.elapsed_s,
            "prefetch should shorten multi-chain runs: {} !< {}",
            yes.elapsed_s,
            no.elapsed_s
        );
    }

    #[test]
    fn nvlink_beats_pcie() {
        let p = run_with(GpuOpts::default(), Link::PciE, true, SMALL_HBM, 2);
        let n = run_with(GpuOpts::default(), Link::NvLink, true, SMALL_HBM, 2);
        assert!(n.elapsed_s < p.elapsed_s);
    }

    #[test]
    fn numerics_match_untiled_reference() {
        let (datasets, stencils, _, chain) = fixture(512);
        // Reference: plain untiled execution.
        let mut store_ref = DataStore::new();
        datasets.iter().for_each(|d| store_ref.alloc(d));
        let mut reds_ref: Vec<Reduction> = vec![];
        let mut exec_ref = NativeExecutor::new();
        for l in &chain {
            exec_ref.run_loop(l, l.range, &datasets, &mut store_ref, &mut reds_ref);
        }
        // Tiled streaming execution.
        let mut store = DataStore::new();
        datasets.iter().for_each(|d| store.alloc(d));
        let mut reds: Vec<Reduction> = vec![];
        let mut metrics = Metrics::new();
        let mut exec = NativeExecutor::new();
        let calib = GpuCalib {
            hbm_bytes: SMALL_HBM,
            ..GpuCalib::default()
        };
        let mut e = GpuExplicitEngine::new(calib, APP, Link::PciE, GpuOpts::default()).unwrap();
        {
            let mut world = World {
                datasets: &datasets,
                stencils: &stencils,
                store: &mut store,
                reds: &mut reds,
                metrics: &mut metrics,
                exec: &mut exec,
            };
            e.run_chain(&chain, &mut world, true);
        }
        for d in &datasets {
            assert_eq!(store_ref.buf(d.id), store.buf(d.id), "dataset {}", d.name);
        }
        assert!(metrics.tiles >= 3, "expected multiple tiles");
    }

    #[test]
    fn fixed_plans_fall_back_when_over_capacity() {
        let (datasets, stencils, _store, chain) = fixture(512);
        let calib = GpuCalib {
            hbm_bytes: SMALL_HBM,
            ..GpuCalib::default()
        };
        let run_src = |plan_src: PlanSource| {
            let mut store = DataStore::new();
            datasets.iter().for_each(|d| store.alloc(d));
            let mut reds = vec![];
            let mut metrics = Metrics::new();
            let mut exec = NativeExecutor::new();
            let mut e =
                GpuExplicitEngine::new(calib.clone(), APP, Link::PciE, GpuOpts::default()).unwrap();
            e.plan = plan_src;
            let mut world = World {
                datasets: &datasets,
                stencils: &stencils,
                store: &mut store,
                reds: &mut reds,
                metrics: &mut metrics,
                exec: &mut exec,
            };
            e.run_chain(&chain, &mut world, true);
            metrics
        };
        let auto = run_src(PlanSource::Auto);
        let over = run_src(PlanSource::Fixed(1));
        assert_eq!(
            over.tiles, auto.tiles,
            "an over-capacity fixed count must fall back to auto sizing"
        );
        let ok = run_src(PlanSource::Fixed(auto.tiles as usize + 2));
        assert_eq!(ok.tiles, auto.tiles + 2, "feasible fixed counts are honoured");
    }

    #[test]
    fn invalid_slot_counts_are_typed_errors() {
        for slots in [0u8, 1, 4, 255] {
            let opts = GpuOpts {
                slots,
                ..GpuOpts::default()
            };
            let e = GpuExplicitEngine::new(GpuCalib::default(), APP, Link::PciE, opts)
                .map(|_| ())
                .unwrap_err();
            let msg = e.to_string();
            assert!(
                msg.contains("GpuOpts::slots") && msg.contains(&slots.to_string()),
                "slots {slots}: {msg}"
            );
        }
        for slots in [2u8, 3] {
            assert!(GpuOpts {
                slots,
                ..GpuOpts::default()
            }
            .validate()
            .is_ok());
        }
    }

    #[test]
    fn reset_transient_clears_prefetch_credit() {
        // Two chains with prefetch: the second normally starts with
        // upload credit. Resetting between chains must reproduce the
        // no-credit (cold) second chain exactly.
        let run_pair = |reset: bool| -> f64 {
            let (datasets, stencils, mut store, chain) = fixture(512);
            let mut reds = vec![];
            let mut metrics = Metrics::new();
            let mut exec = NativeExecutor::new();
            let calib = GpuCalib {
                hbm_bytes: SMALL_HBM,
                ..GpuCalib::default()
            };
            let mut e =
                GpuExplicitEngine::new(calib, APP, Link::PciE, GpuOpts::default()).unwrap();
            for i in 0..2 {
                if reset && i == 1 {
                    e.reset_transient();
                }
                let mut world = World {
                    datasets: &datasets,
                    stencils: &stencils,
                    store: &mut store,
                    reds: &mut reds,
                    metrics: &mut metrics,
                    exec: &mut exec,
                };
                e.run_chain(&chain, &mut world, true);
            }
            metrics.elapsed_s
        };
        let warm = run_pair(false);
        let cold = run_pair(true);
        assert!(
            cold > warm,
            "resetting the credit must lose the prefetch overlap: {cold} !> {warm}"
        );
    }

    #[test]
    fn streams_are_attributed_and_bound_is_reported() {
        let m = run_with(GpuOpts::default(), Link::PciE, true, SMALL_HBM, 2);
        for s in ["compute", "upload", "download"] {
            assert!(m.per_resource.contains_key(s), "missing stream {s}");
            assert!(m.per_resource[s].busy_s > 0.0, "stream {s} idle");
        }
        assert_eq!(m.per_resource["upload"].bytes, m.h2d_bytes);
        assert_eq!(m.per_resource["download"].bytes, m.d2h_bytes);
        // a small-HBM PCIe streaming run is transfer-bound
        assert_eq!(m.bound().name(), "upload");
        use crate::exec::timeline::StreamClass;
        assert!(m.stream_util(StreamClass::Upload) > m.stream_util(StreamClass::Compute));
        assert!(m.stream_util(StreamClass::Upload) <= 1.0 + 1e-12);
    }

    #[test]
    fn slot_footprints_respect_capacity() {
        let (datasets, stencils, _, chain) = fixture(512);
        let plan = crate::tiling::plan::plan_auto(
            &chain,
            &datasets,
            &stencils,
            (SMALL_HBM as f64 / 3.0 * 0.92) as u64,
        )
        .unwrap();
        assert!(
            plan.max_footprint_bytes(&datasets) * 3 <= SMALL_HBM,
            "three slots must fit in HBM"
        );
    }
}
