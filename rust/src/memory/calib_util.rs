//! Calibration helpers shared by every memory engine.
//!
//! These used to live in [`super::plain`], which made the flat engine a
//! dependency of every other engine; they are chain-level properties of
//! the calibration methodology, not of any one engine, so they live in
//! their own home.

use crate::exec::World;
use crate::ops::LoopInst;

/// One binary gibibyte — the unit the paper's capacity figures use
/// ("16 GB" MCDRAM/HBM are 16 GiB parts).
pub const GIB: u64 = 1 << 30;
/// One decimal gigabyte — the unit of every bandwidth figure (GB/s).
pub const GB: f64 = 1e9;

/// Normalisation that pins a chain's byte-weighted average bandwidth to
/// the engine's app-calibrated baseline: `Σ B / Σ (B/e)`. Relative
/// per-kernel efficiencies still differentiate kernels (e.g. OpenSBLI's
/// hot RHS), but the *average* matches the paper's measured number —
/// which is exactly the calibration methodology of DESIGN.md §2.
pub(crate) fn chain_bw_norm(world: &World<'_>, chain: &[LoopInst]) -> f64 {
    let mut b = 0.0f64;
    let mut be = 0.0f64;
    for l in chain {
        let bytes = l.bytes_touched(elem_bytes(world, l)) as f64;
        b += bytes;
        be += bytes / l.bw_efficiency;
    }
    if b > 0.0 {
        be / b
    } else {
        1.0
    }
}

/// All our modelled fields share one element size per chain; take it from
/// the first dataset argument (datasets are uniformly scaled).
pub(crate) fn elem_bytes(world: &World<'_>, l: &LoopInst) -> u64 {
    l.dat_args()
        .next()
        .map(|(d, _, _)| world.datasets[d.0 as usize].elem_bytes)
        .unwrap_or(8)
}
