//! KNL cache-mode engine: MCDRAM as a direct-mapped last-level cache over
//! DDR4 (§4, §5.2), with optional skewed tiling sized to the cache.

use super::cache_sim::{AccessResult, AddressMap, CacheSim};
use super::calib_util::{chain_bw_norm, elem_bytes};
use super::halo::HaloModel;
use super::hierarchy::{AppCalib, KnlCalib};
use crate::exec::timeline::{EventKind, StreamClass, Timeline};
use crate::exec::{Engine, World};
use crate::ops::{LoopInst, Range3};
use crate::tiling::analysis::ChainAnalysis;
use crate::tiling::plan::{pick_tile_dim, PlanSource};

/// MCDRAM-as-cache engine.
pub struct KnlEngine {
    pub calib: KnlCalib,
    pub app: AppCalib,
    /// Tiling on/off (the paper's "cache" vs "cache tiled" series).
    pub tiled: bool,
    /// Fraction of MCDRAM a tile footprint may occupy when tiling.
    pub tile_occupancy: f64,
    /// Where tile plans come from (default: auto-size to the occupancy
    /// target; the tuner injects `Fixed` counts here).
    pub plan: PlanSource,
    cache: CacheSim,
    addr: Option<AddressMap>,
    halo: HaloModel,
}

impl KnlEngine {
    pub fn new(calib: KnlCalib, app: AppCalib, tiled: bool) -> Self {
        let cache = CacheSim::new(calib.mcdram_bytes, calib.cache_granule);
        KnlEngine {
            halo: HaloModel {
                latency_s: calib.halo_latency_s,
                ..HaloModel::knl()
            },
            calib,
            app,
            tiled,
            tile_occupancy: 0.15,
            plan: PlanSource::Auto,
            cache,
            addr: None,
        }
    }

    /// The heuristic tile-footprint byte budget when tiling: a fixed
    /// occupancy share of MCDRAM (direct-mapped conflicts make full
    /// occupancy counterproductive). Public for the tuner's search seed.
    pub fn tile_target(&self) -> u64 {
        (self.calib.mcdram_bytes as f64 * self.tile_occupancy) as u64
    }

    /// Time for one loop execution over `range`, driving the cache
    /// simulator with the loop's actual slab accesses.
    ///
    /// MCDRAM-side time is the §5.1 byte count at the app-calibrated
    /// cache-mode bandwidth; DDR4-side time is miss + writeback traffic at
    /// STREAM DDR4 bandwidth; the two streams overlap, so the loop takes
    /// the max.
    #[allow(clippy::too_many_arguments)]
    fn loop_time(
        &mut self,
        l: &LoopInst,
        range: &Range3,
        world: &mut World<'_>,
        tile_dim: usize,
        norm: f64,
    ) -> (f64, AccessResult, f64, f64) {
        let addr = self.addr.as_ref().expect("address map built per chain");
        let mut acc = AccessResult::default();
        for (d, s, a) in l.dat_args() {
            let ds = &world.datasets[d.0 as usize];
            let st = &world.stencils[s.0 as usize];
            let (base, len) = addr.slab(ds, st, range, tile_dim);
            acc.merge(self.cache.access_range(base, len, a.reads(), a.writes()));
        }
        // Fraction of the loop's iteration space inside `range`.
        let frac = {
            let full = crate::ops::parloop::range_points(&l.range).max(1);
            let part = crate::ops::parloop::range_points(range);
            part as f64 / full as f64
        };
        let bytes = (l.bytes_touched(elem_bytes(world, l)) as f64 * frac) as u64;
        let bw_cache = self.app.knl_mcdram * (self.calib.bw_mcdram_cache / self.calib.bw_mcdram_flat);
        let mc_time = bytes as f64 / (bw_cache * l.bw_efficiency * norm * 1e9);
        let ddr_time = acc.ddr_bytes() as f64 / (self.calib.bw_ddr4 * 1e9);
        (mc_time.max(ddr_time), acc, mc_time, ddr_time)
    }
}

impl Engine for KnlEngine {
    fn run_chain(&mut self, chain: &[LoopInst], world: &mut World<'_>, cyclic_phase: bool) {
        self.run_chain_analyzed(chain, None, world, cyclic_phase);
    }

    fn run_chain_analyzed(
        &mut self,
        chain: &[LoopInst],
        analysis: Option<&ChainAnalysis>,
        world: &mut World<'_>,
        _cyclic_phase: bool,
    ) {
        world.metrics.chains += 1;
        let sp = crate::obs::span("knl");
        sp.field("loops", chain.len());
        sp.field("tiled", self.tiled);
        let tile_dim = analysis.map_or_else(|| pick_tile_dim(chain), |a| a.tile_dim);
        if self.addr.is_none() {
            self.addr = Some(AddressMap::new(world.datasets, self.calib.cache_granule));
        }

        // Two overlapping memory streams: MCDRAM-side time and DDR4-side
        // cache-fill traffic pipeline *across* loop boundaries on real
        // hardware, so each loop stacks an event on both resources with
        // no cross edges — the chain's wall time is max(Σ mc, Σ ddr),
        // not Σ max per loop. MPI halo exchanges serialise after the
        // memory streams drain (bulk-synchronous steps), which keeps the
        // makespan at max(Σ mc, Σ ddr) + Σ halo.
        let norm = chain_bw_norm(world, chain);
        let mut tl = Timeline::for_world(world);
        let rm = tl.resource("mcdram", StreamClass::Compute);
        let rd = tl.resource("ddr4", StreamClass::Upload);
        let rh = tl.resource("halo", StreamClass::Exchange);
        // Deferred (label, time) halo events, pushed after the join.
        let mut halos: Vec<(&str, f64)> = Vec::new();
        if !self.tiled {
            for l in chain {
                world
                    .exec
                    .run_loop(l, l.range, world.datasets, world.store, world.reds);
                let (t, acc, mc, ddr) = self.loop_time(l, &l.range.clone(), world, tile_dim, norm);
                let bytes = l.bytes_touched(elem_bytes(world, l));
                world.metrics.record_loop(&l.name, bytes, t);
                tl.push(rm, EventKind::Compute, &l.name, mc, bytes);
                if ddr > 0.0 || acc.ddr_bytes() > 0 {
                    tl.push(rd, EventKind::CacheFill, &l.name, ddr, acc.ddr_bytes());
                }
                world.metrics.cache_hits += acc.hit_granules;
                world.metrics.cache_misses += acc.miss_granules;
                let (ht, n) = self
                    .halo
                    .per_loop_cost(l, world.datasets, world.stencils, tile_dim);
                world.metrics.halo_time_s += ht;
                world.metrics.halo_exchanges += n;
                if n > 0 {
                    world.metrics.obs.record("halo_exchange_s", ht);
                    halos.push((&l.name, ht));
                }
            }
            let drained = tl.cursor(rm).max(tl.cursor(rd));
            tl.wait_until(rh, drained);
            for (name, ht) in halos {
                tl.push(rh, EventKind::Halo, name, ht, 0);
            }
            world.metrics.absorb_timeline(tl);
            return;
        }

        // Tiled: size tiles to MCDRAM and run the skewed schedule. The
        // dependency analysis comes cached when a Session replays the
        // chain; the legacy path rebuilds it here per flush.
        let mut local = None;
        let analysis =
            ChainAnalysis::resolve(analysis, &mut local, chain, world.datasets, world.stencils);
        let plan = self.plan.plan_analyzed(
            chain,
            world.datasets,
            world.stencils,
            self.tile_target(),
            analysis,
        );
        world.metrics.tiles += plan.num_tiles() as u64;
        for (ti, tile) in plan.tiles.iter().enumerate() {
            for (li, r) in tile.loop_ranges.iter().enumerate() {
                let Some(r) = r else { continue };
                let l = &chain[li];
                world
                    .exec
                    .run_loop(l, *r, world.datasets, world.store, world.reds);
                let (t, acc, mc, ddr) = self.loop_time(l, r, world, plan.tile_dim, norm);
                let frac = crate::ops::parloop::range_points(r) as f64
                    / crate::ops::parloop::range_points(&l.range).max(1) as f64;
                let bytes = (l.bytes_touched(elem_bytes(world, l)) as f64 * frac) as u64;
                world.metrics.record_loop(&l.name, bytes, t);
                let label = if tl.tracing() {
                    format!("{} t{ti}", l.name)
                } else {
                    String::new()
                };
                tl.push(rm, EventKind::Compute, &label, mc, bytes);
                if ddr > 0.0 || acc.ddr_bytes() > 0 {
                    tl.push(rd, EventKind::CacheFill, &label, ddr, acc.ddr_bytes());
                }
                world.metrics.cache_hits += acc.hit_granules;
                world.metrics.cache_misses += acc.miss_granules;
            }
        }
        // One aggregate halo exchange per chain (§5.2), after the memory
        // streams drain.
        let max_shift = plan.shifts.first().copied().unwrap_or(0);
        let (ht, n) =
            self.halo
                .per_chain_cost(chain, world.datasets, world.stencils, tile_dim, max_shift);
        world.metrics.halo_time_s += ht;
        world.metrics.halo_exchanges += n;
        let drained = tl.cursor(rm).max(tl.cursor(rd));
        tl.wait_until(rh, drained);
        if n > 0 {
            world.metrics.obs.record("halo_exchange_s", ht);
            tl.push(rh, EventKind::Halo, "chain halo", ht, 0);
        }
        world.metrics.absorb_timeline(tl);
    }

    fn describe(&self) -> String {
        format!(
            "KNL cache mode{} (MCDRAM {} GiB, granule {} MiB)",
            if self.tiled { " + tiling" } else { "" },
            self.calib.mcdram_bytes >> 30,
            self.calib.cache_granule >> 20,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{Metrics, NativeExecutor};
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::ops::*;

    /// Build a synthetic app: `nds` datasets of `ny` rows, a chain that
    /// sweeps all of them `reps` times with a radius-1 stencil.
    fn fixture(
        nds: u32,
        ny: usize,
        reps: usize,
        elem_bytes: u64,
    ) -> (Vec<Dataset>, Vec<Stencil>, DataStore, Vec<LoopInst>) {
        let mut datasets = vec![];
        let mut store = DataStore::new();
        for i in 0..nds {
            let d = Dataset {
                id: DatasetId(i),
                block: BlockId(0),
                name: format!("d{i}"),
                size: [64, ny, 1],
                halo_lo: [2, 2, 0],
                halo_hi: [2, 2, 0],
                elem_bytes,
            };
            store.alloc(&d);
            datasets.push(d);
        }
        let stencils = vec![
            Stencil {
                id: StencilId(0),
                name: "pt".into(),
                points: shapes::point(),
            },
            Stencil {
                id: StencilId(1),
                name: "star".into(),
                points: shapes::star2d(1),
            },
        ];
        let mut chain = vec![];
        for r in 0..reps {
            for i in 0..nds {
                let src = DatasetId(i);
                let dst = DatasetId((i + 1) % nds);
                chain.push(LoopInst {
                    name: format!("sweep{r}_{i}"),
                    block: BlockId(0),
                    range: [(0, 64), (0, ny as isize), (0, 1)],
                    args: vec![
                        Arg::dat(src, StencilId(1), Access::Read),
                        Arg::dat(dst, StencilId(0), Access::Write),
                    ],
                    kernel: kernel(|c| {
                        let v = c.r(0, 0, 0) + c.r(0, 1, 0);
                        c.w(1, 0, 0, v);
                    }),
                    kernel_ir: None,
                    seq: (r * nds as usize + i as usize) as u64,
                    bw_efficiency: 1.0,
                });
            }
        }
        (datasets, stencils, store, chain)
    }

    fn run(engine: &mut KnlEngine, fixture_parts: (Vec<Dataset>, Vec<Stencil>, DataStore, Vec<LoopInst>)) -> Metrics {
        let (datasets, stencils, mut store, chain) = fixture_parts;
        let mut reds = vec![];
        let mut metrics = Metrics::new();
        let mut exec = NativeExecutor::new();
        let mut world = World {
            datasets: &datasets,
            stencils: &stencils,
            store: &mut store,
            reds: &mut reds,
            metrics: &mut metrics,
            exec: &mut exec,
        };
        engine.run_chain(&chain, &mut world, false);
        metrics
    }

    /// Tiny calibration so test problems exercise the cache boundaries:
    /// 1 MiB "MCDRAM", 4 KiB granules.
    fn small_calib() -> KnlCalib {
        KnlCalib {
            mcdram_bytes: 1 << 20,
            cache_granule: 4 << 10,
            ..KnlCalib::default()
        }
    }

    const APP: AppCalib = AppCalib {
        knl_ddr4: 50.0,
        knl_mcdram: 240.0,
        gpu: 470.0,
    };

    #[test]
    fn fitting_problem_hits_after_warmup() {
        // 4 datasets x 64x64 x 8B ≈ 150 KiB << 1 MiB cache.
        let mut e = KnlEngine::new(small_calib(), APP, false);
        let m = run(&mut e, fixture(4, 64, 4, 8));
        assert!(
            m.cache_hit_rate() > 0.7,
            "hit rate {} too low for fitting problem",
            m.cache_hit_rate()
        );
    }

    #[test]
    fn oversubscribed_untiled_thrashes_but_tiled_recovers() {
        // 8 datasets x 64x768 x 8B ≈ 3 MiB = 3x the 1 MiB "MCDRAM".
        let mut e_untiled = KnlEngine::new(small_calib(), APP, false);
        let m_untiled = run(&mut e_untiled, fixture(8, 768, 3, 8));
        let mut e_tiled = KnlEngine::new(small_calib(), APP, true);
        let m_tiled = run(&mut e_tiled, fixture(8, 768, 3, 8));

        assert!(
            m_tiled.cache_hit_rate() > 0.55,
            "tiled hit rate {:.2} too low",
            m_tiled.cache_hit_rate()
        );
        assert!(
            m_tiled.cache_hit_rate() > m_untiled.cache_hit_rate() + 0.1,
            "tiled hit rate {:.2} should beat untiled {:.2}",
            m_tiled.cache_hit_rate(),
            m_untiled.cache_hit_rate()
        );
        assert!(
            m_tiled.effective_bandwidth_gbs() > m_untiled.effective_bandwidth_gbs(),
            "tiling should improve effective bandwidth"
        );
    }

    #[test]
    fn tiled_and_untiled_numerics_agree() {
        let fx = fixture(4, 256, 3, 8);
        let (datasets, stencils, _, chain) = &fx;
        // untiled
        let mut store_a = DataStore::new();
        datasets.iter().for_each(|d| store_a.alloc(d));
        let mut reds_a: Vec<Reduction> = vec![];
        let mut metrics_a = Metrics::new();
        let mut exec_a = NativeExecutor::new();
        {
            let mut world = World {
                datasets,
                stencils,
                store: &mut store_a,
                reds: &mut reds_a,
                metrics: &mut metrics_a,
                exec: &mut exec_a,
            };
            let mut e = KnlEngine::new(small_calib(), APP, false);
            e.run_chain(chain, &mut world, false);
        }
        // tiled
        let mut store_b = DataStore::new();
        datasets.iter().for_each(|d| store_b.alloc(d));
        let mut reds_b: Vec<Reduction> = vec![];
        let mut metrics_b = Metrics::new();
        let mut exec_b = NativeExecutor::new();
        {
            let mut world = World {
                datasets,
                stencils,
                store: &mut store_b,
                reds: &mut reds_b,
                metrics: &mut metrics_b,
                exec: &mut exec_b,
            };
            let mut e = KnlEngine::new(small_calib(), APP, true);
            e.run_chain(chain, &mut world, false);
        }
        for d in datasets {
            assert_eq!(
                store_a.buf(d.id),
                store_b.buf(d.id),
                "tiled execution must be bit-identical for {}",
                d.name
            );
        }
    }

    #[test]
    fn tiles_created_only_when_tiling() {
        let mut e = KnlEngine::new(small_calib(), APP, true);
        let m = run(&mut e, fixture(8, 768, 1, 8));
        assert!(m.tiles >= 3, "expected >=3 tiles, got {}", m.tiles);
        let mut e2 = KnlEngine::new(small_calib(), APP, false);
        let m2 = run(&mut e2, fixture(8, 768, 1, 8));
        assert_eq!(m2.tiles, 0);
    }
}
