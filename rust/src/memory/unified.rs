//! Unified-memory engine (§5.4): GPU memory as a page cache over host
//! memory. Pages migrate on fault (high latency, no automatic prefetch);
//! optional bulk `cudaMemPrefetchAsync`-style prefetches move tile
//! footprints at link bandwidth, degraded under oversubscription.

use super::cache_sim::AddressMap;
use super::calib_util::{chain_bw_norm, elem_bytes};
use super::hierarchy::{AppCalib, GpuCalib, Link, UnifiedCalib, GB};
use crate::exec::timeline::{EventKind, StreamClass, Timeline};
use crate::exec::{Engine, World};
use crate::ops::{LoopInst, Range3};
use crate::tiling::analysis::ChainAnalysis;
use crate::tiling::plan::{pick_tile_dim, PlanSource};
use std::collections::{BTreeMap, HashMap};

/// Exact LRU set of resident pages: page → last-use tick, plus an order
/// index (tick → page; ticks are unique because they're monotonic).
/// touch and evict are both O(log n) — this was the §Perf hot spot of the
/// unified-memory figure (see EXPERIMENTS.md §Perf: 5.6x on fig11).
#[derive(Debug, Default)]
struct ResidentSet {
    pages: HashMap<u64, u64>,
    order: BTreeMap<u64, u64>,
    tick: u64,
}

impl ResidentSet {
    /// Touch pages `[p0, p1)`; returns how many were absent (faults).
    fn touch_range(&mut self, p0: u64, p1: u64, cap_pages: u64) -> u64 {
        let mut faults = 0;
        for p in p0..p1 {
            self.tick += 1;
            if let Some(old) = self.pages.insert(p, self.tick) {
                self.order.remove(&old);
            } else {
                faults += 1;
            }
            self.order.insert(self.tick, p);
            if self.pages.len() as u64 > cap_pages {
                if let Some((_, victim)) = self.order.pop_first() {
                    self.pages.remove(&victim);
                }
            }
        }
        faults
    }

    /// Resident page count (diagnostics).
    #[allow(dead_code)]
    fn len(&self) -> usize {
        self.pages.len()
    }
}

/// Unified-memory engine.
pub struct UnifiedEngine {
    pub gpu: GpuCalib,
    pub um: UnifiedCalib,
    pub app: AppCalib,
    pub link: Link,
    /// Run the skewed tiling schedule (vs. untiled loop order).
    pub tiled: bool,
    /// Issue bulk prefetches per tile instead of relying on faults.
    pub prefetch: bool,
    /// Where tile plans come from when tiled (default: auto-size to the
    /// HBM occupancy target; the tuner injects `Fixed` counts here).
    pub plan: PlanSource,
    resident: ResidentSet,
    addr: Option<AddressMap>,
}

impl UnifiedEngine {
    pub fn new(
        gpu: GpuCalib,
        um: UnifiedCalib,
        app: AppCalib,
        link: Link,
        tiled: bool,
        prefetch: bool,
    ) -> Self {
        UnifiedEngine {
            gpu,
            um,
            app,
            link,
            tiled,
            prefetch,
            plan: PlanSource::Auto,
            resident: ResidentSet::default(),
            addr: None,
        }
    }

    /// The heuristic tile-footprint byte budget when tiling: most of HBM,
    /// leaving room for the driver's own residency bookkeeping. Public
    /// for the tuner's search seed.
    pub fn tile_target(&self) -> u64 {
        (self.gpu.hbm_bytes as f64 * 0.8) as u64
    }

    fn cap_pages(&self) -> u64 {
        self.gpu.hbm_bytes / self.um.page_bytes
    }

    /// Cost of faulting one resident-set page in: the page moves as
    /// small fault groups, each latency-bound — identical on PCIe and
    /// NVLink (§5.4's observation).
    fn fault_cost(&self) -> f64 {
        let chunks = self.um.page_bytes.div_ceil(self.um.fault_chunk_bytes) as f64;
        let per_chunk = self
            .um
            .fault_latency_s
            .max(self.um.fault_chunk_bytes as f64 / (self.link.spec().bw_gbs * GB));
        chunks * per_chunk
    }

    fn compute_time(&self, l: &LoopInst, bytes: u64, norm: f64) -> f64 {
        bytes as f64 / (self.app.gpu * l.bw_efficiency * norm * GB) + self.gpu.launch_s
    }

    /// Touch every page a loop-range accesses; returns fault count.
    ///
    /// Pure-`Write` (write-first) arguments populate managed pages on the
    /// device without a migration (cudaMallocManaged first-touch), so
    /// they become resident for free; reads and read-modify-writes of
    /// non-resident pages pay the fault path.
    fn touch_loop(&mut self, l: &LoopInst, range: &Range3, world: &World<'_>, tile_dim: usize) -> u64 {
        let addr = self.addr.as_ref().unwrap();
        let pg = self.um.page_bytes;
        let cap = self.cap_pages();
        let mut faults = 0;
        for (d, s, a) in l.dat_args() {
            let ds = &world.datasets[d.0 as usize];
            let st = &world.stencils[s.0 as usize];
            let (base, len) = addr.slab(ds, st, range, tile_dim);
            if len == 0 {
                continue;
            }
            let p0 = base / pg;
            let p1 = (base + len - 1) / pg + 1;
            let absent = self.resident.touch_range(p0, p1, cap);
            if a.reads() {
                faults += absent;
            }
        }
        faults
    }
}

impl Engine for UnifiedEngine {
    fn run_chain(&mut self, chain: &[LoopInst], world: &mut World<'_>, cyclic_phase: bool) {
        self.run_chain_analyzed(chain, None, world, cyclic_phase);
    }

    fn run_chain_analyzed(
        &mut self,
        chain: &[LoopInst],
        analysis: Option<&ChainAnalysis>,
        world: &mut World<'_>,
        _cyclic_phase: bool,
    ) {
        world.metrics.chains += 1;
        let sp = crate::obs::span("unified");
        sp.field("loops", chain.len());
        sp.field("tiled", self.tiled);
        let tile_dim = analysis.map_or_else(|| pick_tile_dim(chain), |a| a.tile_dim);
        let norm = chain_bw_norm(world, chain);
        if self.addr.is_none() {
            self.addr = Some(AddressMap::new(world.datasets, self.um.page_bytes));
        }

        // Two streams: the compute stream and a `migration` stream for
        // page traffic. On-demand faults *stall* compute (the faulting
        // kernel cannot proceed), so fault events carry a dependency
        // edge back into the compute stream; bulk prefetches overlap
        // the previous tile's compute and only their uncovered tail
        // stalls.
        let mut tl = Timeline::for_world(world);
        let rc = tl.resource("compute", StreamClass::Compute);
        let rm = tl.resource("migration", StreamClass::Upload);

        if !self.tiled {
            // Untiled unified memory: loops fault pages in as they sweep.
            for l in chain {
                world
                    .exec
                    .run_loop(l, l.range, world.datasets, world.store, world.reds);
                let faults = self.touch_loop(l, &l.range.clone(), world, tile_dim);
                let bytes = l.bytes_touched(elem_bytes(world, l));
                let fault_t = faults as f64 * self.fault_cost();
                let ct = self.compute_time(l, bytes, norm);
                let t = ct + fault_t;
                world.metrics.record_loop(&l.name, bytes, t);
                if faults > 0 {
                    let at = tl.cursor(rc);
                    let end = tl.push_at(
                        rm,
                        EventKind::Fault,
                        &l.name,
                        at,
                        fault_t,
                        faults * self.um.page_bytes,
                    );
                    tl.wait_until(rc, end);
                }
                tl.push(rc, EventKind::Compute, &l.name, ct, bytes);
                world.metrics.page_faults += faults;
                world.metrics.h2d_bytes += faults * self.um.page_bytes;
            }
            world.metrics.absorb_timeline(tl);
            return;
        }

        // Tiled: tiles sized to HBM; with prefetch, each tile's footprint
        // is bulk-moved while the previous tile computes. The dependency
        // analysis comes cached when a Session replays the chain; the
        // legacy path rebuilds it here per flush.
        let mut local = None;
        let analysis =
            ChainAnalysis::resolve(analysis, &mut local, chain, world.datasets, world.stencils);
        let plan = self.plan.plan_analyzed(
            chain,
            world.datasets,
            world.stencils,
            self.tile_target(),
            analysis,
        );
        world.metrics.tiles += plan.num_tiles() as u64;
        let oversub = analysis.chain_bytes > self.gpu.hbm_bytes;
        let mut prev_tile_compute = 0.0f64;

        for (ti, tile) in plan.tiles.iter().enumerate() {
            // Count the faults/prefetch traffic for this tile *before*
            // running it: pages touched by any loop range of the tile.
            let mut tile_faults = 0u64;
            for (li, r) in tile.loop_ranges.iter().enumerate() {
                let Some(r) = r else { continue };
                tile_faults += self.touch_loop(&chain[li], r, world, plan.tile_dim);
            }

            let mig_bytes = tile_faults * self.um.page_bytes;
            let label = if tl.tracing() {
                format!("tile {ti}")
            } else {
                String::new()
            };
            let stall;
            if self.prefetch {
                // Bulk prefetch at (degraded) link bandwidth: the event
                // starts `overlap` seconds before the previous tile's
                // compute ends, so only its uncovered tail stalls the
                // compute stream.
                let eff = if oversub {
                    self.um.prefetch_eff_oversub
                } else {
                    self.um.prefetch_eff
                };
                let t_pf = mig_bytes as f64 / (self.link.spec().bw_gbs * eff * GB);
                let overlap = prev_tile_compute * self.um.prefetch_overlap;
                stall = (t_pf - overlap).max(0.0);
                if tile_faults > 0 {
                    // Overlapping push: prefetches pipeline (contention
                    // lives in `eff`), so this tile's transfer starts in
                    // its own overlap window regardless of the previous
                    // tile's prefetch — exactly the closed-form model.
                    let at = tl.cursor(rc) - overlap;
                    let end =
                        tl.push_overlapping(rm, EventKind::Prefetch, &label, at, t_pf, mig_bytes);
                    tl.wait_until(rc, end);
                }
            } else {
                stall = tile_faults as f64 * self.fault_cost();
                if tile_faults > 0 {
                    let at = tl.cursor(rc);
                    let end = tl.push_at(rm, EventKind::Fault, &label, at, stall, mig_bytes);
                    tl.wait_until(rc, end);
                }
            }
            world.metrics.page_faults += tile_faults;
            world.metrics.h2d_bytes += mig_bytes;

            // `tile_compute` keeps the legacy stall-inclusive accounting:
            // the §5.1 per-loop times (and the next tile's overlap
            // window) charge the stall to the tile's first loop, while
            // the timeline models it as the dependency edge above.
            let mut tile_compute = 0.0;
            let mut first_loop_in_tile = true;
            for (li, r) in tile.loop_ranges.iter().enumerate() {
                let Some(r) = r else { continue };
                let l = &chain[li];
                world
                    .exec
                    .run_loop(l, *r, world.datasets, world.store, world.reds);
                let frac = crate::ops::parloop::range_points(r) as f64
                    / crate::ops::parloop::range_points(&l.range).max(1) as f64;
                let bytes = (l.bytes_touched(elem_bytes(world, l)) as f64 * frac) as u64;
                let ct = self.compute_time(l, bytes, norm);
                tl.push(rc, EventKind::Compute, &l.name, ct, bytes);
                let mut t = ct;
                if first_loop_in_tile {
                    // The migration stall lands on the tile's first loop.
                    t += stall;
                    first_loop_in_tile = false;
                }
                world.metrics.record_loop(&l.name, bytes, t);
                tile_compute += t;
            }
            world.metrics.obs.record("tile_compute_s", tile_compute);
            prev_tile_compute = tile_compute;
        }
        world.metrics.absorb_timeline(tl);
    }

    fn describe(&self) -> String {
        format!(
            "GPU unified memory {}{}{}",
            self.link.name(),
            if self.tiled { " + tiling" } else { "" },
            if self.prefetch { " + prefetch" } else { "" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{Metrics, NativeExecutor};
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::{shapes, StencilId};
    use crate::exec::Executor;
    use crate::ops::*;

    const APP: AppCalib = AppCalib {
        knl_ddr4: 50.0,
        knl_mcdram: 240.0,
        gpu: 470.0,
    };

    fn fixture(nds: u32, ny: usize) -> (Vec<Dataset>, Vec<Stencil>, DataStore, Vec<LoopInst>) {
        let mut datasets = vec![];
        let mut store = DataStore::new();
        for i in 0..nds {
            let d = Dataset {
                id: DatasetId(i),
                block: BlockId(0),
                name: format!("d{i}"),
                size: [64, ny, 1],
                halo_lo: [2, 2, 0],
                halo_hi: [2, 2, 0],
                elem_bytes: 8,
            };
            store.alloc(&d);
            datasets.push(d);
        }
        let stencils = vec![
            Stencil {
                id: StencilId(0),
                name: "pt".into(),
                points: shapes::point(),
            },
            Stencil {
                id: StencilId(1),
                name: "star".into(),
                points: shapes::star2d(1),
            },
        ];
        let mut chain = vec![];
        for i in 0..nds {
            chain.push(LoopInst {
                name: format!("sweep{i}"),
                block: BlockId(0),
                range: [(0, 64), (0, ny as isize), (0, 1)],
                args: vec![
                    Arg::dat(DatasetId(i), StencilId(1), Access::Read),
                    Arg::dat(DatasetId((i + 1) % nds), StencilId(0), Access::ReadWrite),
                ],
                kernel: kernel(|c| {
                    let v = c.r(0, 0, -1) + c.r(0, 0, 1);
                    let old = c.r(1, 0, 0);
                    c.w(1, 0, 0, v + 0.01 * old);
                }),
                kernel_ir: None,
                seq: i as u64,
                bw_efficiency: 1.0,
            });
        }
        (datasets, stencils, store, chain)
    }

    fn small_gpu(hbm: u64) -> (GpuCalib, UnifiedCalib) {
        (
            GpuCalib {
                hbm_bytes: hbm,
                ..GpuCalib::default()
            },
            UnifiedCalib {
                page_bytes: 4 << 10,
                ..UnifiedCalib::default()
            },
        )
    }

    fn run(e: &mut UnifiedEngine, chains: usize, fx: &(Vec<Dataset>, Vec<Stencil>, DataStore, Vec<LoopInst>)) -> Metrics {
        let (datasets, stencils, _, chain) = fx;
        let mut store = DataStore::new();
        datasets.iter().for_each(|d| store.alloc(d));
        let mut reds = vec![];
        let mut metrics = Metrics::new();
        let mut exec = NativeExecutor::new();
        for _ in 0..chains {
            let mut world = World {
                datasets,
                stencils,
                store: &mut store,
                reds: &mut reds,
                metrics: &mut metrics,
                exec: &mut exec,
            };
            e.run_chain(chain, &mut world, true);
        }
        metrics
    }

    #[test]
    fn fitting_problem_faults_only_once() {
        let fx = fixture(4, 256);
        let (gpu, um) = small_gpu(16 << 20); // plenty
        let mut e = UnifiedEngine::new(gpu, um, APP, Link::PciE, false, false);
        let m = run(&mut e, 3, &fx);
        // After the first chain everything is resident: fault count equals
        // the first chain's pages.
        let total_pages: u64 = fx.0.iter().map(|d| d.bytes().div_ceil(4 << 10) + 1).sum();
        assert!(m.page_faults <= total_pages, "{} > {}", m.page_faults, total_pages);
    }

    #[test]
    fn oversubscribed_untiled_collapses() {
        let fx = fixture(8, 1024); // ~4.3 MiB total
        let (gpu, um) = small_gpu(1 << 20); // 1 MiB "HBM"
        let mut small = UnifiedEngine::new(gpu.clone(), um.clone(), APP, Link::PciE, false, false);
        let m_small = run(&mut small, 6, &fx);
        let (gpu_big, um2) = small_gpu(64 << 20);
        let mut big = UnifiedEngine::new(gpu_big, um2, APP, Link::PciE, false, false);
        let m_big = run(&mut big, 6, &fx);
        assert!(
            m_small.effective_bandwidth_gbs() < m_big.effective_bandwidth_gbs() / 3.0,
            "oversubscription should collapse performance: {} vs {}",
            m_small.effective_bandwidth_gbs(),
            m_big.effective_bandwidth_gbs()
        );
    }

    #[test]
    fn tiling_recovers_some_performance() {
        let fx = fixture(8, 1024);
        let (gpu, um) = small_gpu(1 << 20);
        let mut untiled = UnifiedEngine::new(gpu.clone(), um.clone(), APP, Link::PciE, false, false);
        let m_untiled = run(&mut untiled, 2, &fx);
        let mut tiled = UnifiedEngine::new(gpu.clone(), um.clone(), APP, Link::PciE, true, false);
        let m_tiled = run(&mut tiled, 2, &fx);
        let mut pf = UnifiedEngine::new(gpu, um, APP, Link::PciE, true, true);
        let m_pf = run(&mut pf, 2, &fx);
        assert!(
            m_tiled.effective_bandwidth_gbs() > m_untiled.effective_bandwidth_gbs(),
            "tiled {} !> untiled {}",
            m_tiled.effective_bandwidth_gbs(),
            m_untiled.effective_bandwidth_gbs()
        );
        assert!(
            m_pf.effective_bandwidth_gbs() > m_tiled.effective_bandwidth_gbs(),
            "prefetch {} !> tiled {}",
            m_pf.effective_bandwidth_gbs(),
            m_tiled.effective_bandwidth_gbs()
        );
    }

    #[test]
    fn numerics_unchanged_by_unified_tiling() {
        let fx = fixture(4, 512);
        let (datasets, stencils, _, chain) = &fx;
        let mut store_ref = DataStore::new();
        datasets.iter().for_each(|d| store_ref.alloc(d));
        let mut reds_ref: Vec<Reduction> = vec![];
        let mut exec_ref = NativeExecutor::new();
        for l in chain {
            exec_ref.run_loop(l, l.range, datasets, &mut store_ref, &mut reds_ref);
        }
        let (gpu, um) = small_gpu(256 << 10);
        let mut e = UnifiedEngine::new(gpu, um, APP, Link::NvLink, true, true);
        let mut store = DataStore::new();
        datasets.iter().for_each(|d| store.alloc(d));
        let mut reds = vec![];
        let mut metrics = Metrics::new();
        let mut exec = NativeExecutor::new();
        {
            let mut world = World {
                datasets,
                stencils,
                store: &mut store,
                reds: &mut reds,
                metrics: &mut metrics,
                exec: &mut exec,
            };
            e.run_chain(chain, &mut world, true);
        }
        for d in datasets {
            assert_eq!(store_ref.buf(d.id), store.buf(d.id));
        }
    }
}
