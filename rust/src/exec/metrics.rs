//! Metrics, chief among them the paper's §5.1 **Average Bandwidth**:
//! per-loop bytes touched (1× for reads or writes, 2× for read+write)
//! divided by per-loop modelled runtime, weighted-averaged over all loops
//! — equivalently, total useful bytes over total loop time.

use std::collections::HashMap;

/// Accumulated statistics for one kernel name.
#[derive(Debug, Clone, Default)]
pub struct LoopStat {
    pub invocations: u64,
    pub bytes: u64,
    pub time_s: f64,
}

impl LoopStat {
    pub fn bandwidth_gbs(&self) -> f64 {
        if self.time_s > 0.0 {
            self.bytes as f64 / self.time_s / 1e9
        } else {
            0.0
        }
    }
}

/// Per-rank statistics of sharded execution (accumulated across chains).
#[derive(Debug, Clone, Copy, Default)]
pub struct RankStat {
    /// Modelled compute makespan of this rank's sub-chains, seconds.
    pub compute_s: f64,
    /// Modelled inter-rank halo-exchange time, seconds.
    pub exchange_s: f64,
    /// Halo bytes this rank received.
    pub exchange_bytes: u64,
    /// §5.1 bytes touched by this rank's loop slices.
    pub loop_bytes: u64,
    /// Modelled loop time of this rank's slices, seconds.
    pub loop_time_s: f64,
}

impl RankStat {
    /// This rank's weighted Average Bandwidth (§5.1), GB/s.
    pub fn average_bandwidth_gbs(&self) -> f64 {
        if self.loop_time_s > 0.0 {
            self.loop_bytes as f64 / self.loop_time_s / 1e9
        } else {
            0.0
        }
    }
}

/// Simulation-wide metrics sink.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Σ bytes touched by loop bodies (§5.1 accounting).
    pub loop_bytes: u64,
    /// Σ modelled loop runtime, seconds.
    pub loop_time_s: f64,
    /// Wall-clock of the whole simulated schedule (≥ loop time when
    /// transfers don't overlap; < Σ component times when they do).
    pub elapsed_s: f64,
    /// Host→device bytes moved (explicit/unified GPU engines).
    pub h2d_bytes: u64,
    /// Device→host bytes moved.
    pub d2h_bytes: u64,
    /// Device→device bytes (tile edge copies).
    pub d2d_bytes: u64,
    /// MCDRAM-cache statistics (KNL cache mode).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Unified-memory page faults serviced.
    pub page_faults: u64,
    /// Time spent in (modelled) halo exchanges.
    pub halo_time_s: f64,
    /// Number of halo exchanges performed.
    pub halo_exchanges: u64,
    /// Number of loop chains executed.
    pub chains: u64,
    /// Number of tiles executed (0 if untiled).
    pub tiles: u64,
    /// Auto-tuner: cost-model evaluations spent (0 when tuning is off).
    pub tune_evals: u64,
    /// Auto-tuner: chains whose plan came from the tuned-plan cache.
    pub tune_cache_hits: u64,
    /// Auto-tuner: Σ modelled (cold-engine) chain time of the chosen
    /// plans, seconds.
    pub tuned_model_s: f64,
    /// Auto-tuner: Σ modelled chain time of the `HBM/3` heuristic plans
    /// — per chain, `tuned_model_s` never exceeds this.
    pub heuristic_model_s: f64,
    /// Chain analyses computed (or adopted from a frozen Program) by
    /// this run. The legacy eager path re-analyses at every flush, so it
    /// counts one per non-empty chain; a replayed Session counts one per
    /// *distinct* chain shape.
    pub analysis_builds: u64,
    /// Chain executions that reused a cached analysis instead of
    /// re-running the dependency/footprint/skew computation.
    pub analysis_reuse_hits: u64,
    /// Host seconds spent freezing the Program (declaration validation +
    /// per-chain analysis), charged once per Session.
    pub program_freeze_s: f64,
    /// Per-kernel-name breakdown.
    pub per_loop: HashMap<String, LoopStat>,
    /// Per-rank breakdown of sharded execution (empty when unsharded).
    pub per_rank: Vec<RankStat>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one loop execution (possibly one tile's slice of it).
    pub fn record_loop(&mut self, name: &str, bytes: u64, time_s: f64) {
        self.loop_bytes += bytes;
        self.loop_time_s += time_s;
        let st = self.per_loop.entry(name.to_string()).or_default();
        st.invocations += 1;
        st.bytes += bytes;
        st.time_s += time_s;
    }

    /// The headline metric: weighted Average Bandwidth in GB/s.
    pub fn average_bandwidth_gbs(&self) -> f64 {
        if self.loop_time_s > 0.0 {
            self.loop_bytes as f64 / self.loop_time_s / 1e9
        } else {
            0.0
        }
    }

    /// Average bandwidth against *wall* time (includes non-overlapped
    /// transfer and halo time) — what problem-scaling figures plot.
    pub fn effective_bandwidth_gbs(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.loop_bytes as f64 / self.elapsed_s / 1e9
        } else {
            0.0
        }
    }

    /// Modelled speedup of tuned plans over the `HBM/3` heuristic:
    /// Σ heuristic model time / Σ tuned model time. 1.0 when tuning is
    /// off (or everywhere chose the heuristic); never below 1.0 by the
    /// tuner's never-worse guarantee.
    pub fn tune_model_speedup(&self) -> f64 {
        if self.tuned_model_s > 0.0 {
            self.heuristic_model_s / self.tuned_model_s
        } else {
            1.0
        }
    }

    /// MCDRAM cache hit rate in `[0, 1]` (1.0 when no cache modelled).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            1.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Merge another metrics block into this one (used by sweep drivers).
    pub fn merge(&mut self, other: &Metrics) {
        self.loop_bytes += other.loop_bytes;
        self.loop_time_s += other.loop_time_s;
        self.elapsed_s += other.elapsed_s;
        self.h2d_bytes += other.h2d_bytes;
        self.d2h_bytes += other.d2h_bytes;
        self.d2d_bytes += other.d2d_bytes;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.page_faults += other.page_faults;
        self.halo_time_s += other.halo_time_s;
        self.halo_exchanges += other.halo_exchanges;
        self.chains += other.chains;
        self.tiles += other.tiles;
        self.tune_evals += other.tune_evals;
        self.tune_cache_hits += other.tune_cache_hits;
        self.tuned_model_s += other.tuned_model_s;
        self.heuristic_model_s += other.heuristic_model_s;
        self.analysis_builds += other.analysis_builds;
        self.analysis_reuse_hits += other.analysis_reuse_hits;
        self.program_freeze_s += other.program_freeze_s;
        for (k, v) in &other.per_loop {
            let st = self.per_loop.entry(k.clone()).or_default();
            st.invocations += v.invocations;
            st.bytes += v.bytes;
            st.time_s += v.time_s;
        }
        if self.per_rank.len() < other.per_rank.len() {
            self.per_rank.resize(other.per_rank.len(), RankStat::default());
        }
        for (r, v) in other.per_rank.iter().enumerate() {
            let st = &mut self.per_rank[r];
            st.compute_s += v.compute_s;
            st.exchange_s += v.exchange_s;
            st.exchange_bytes += v.exchange_bytes;
            st.loop_bytes += v.loop_bytes;
            st.loop_time_s += v.loop_time_s;
        }
    }

    /// Kernel names sorted by time share, descending — profiling report.
    pub fn hottest(&self, n: usize) -> Vec<(String, LoopStat)> {
        let mut v: Vec<_> = self
            .per_loop
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| b.1.time_s.total_cmp(&a.1.time_s));
        v.truncate(n);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_bandwidth_is_weighted() {
        let mut m = Metrics::new();
        // 100 GB in 1 s + 100 GB in 3 s → 200 GB / 4 s = 50 GB/s,
        // NOT the arithmetic mean of 100 and 33.3.
        m.record_loop("a", 100_000_000_000, 1.0);
        m.record_loop("b", 100_000_000_000, 3.0);
        assert!((m.average_bandwidth_gbs() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn hit_rate_defaults_to_one() {
        let m = Metrics::new();
        assert_eq!(m.cache_hit_rate(), 1.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics::new();
        a.record_loop("k", 10, 1.0);
        let mut b = Metrics::new();
        b.record_loop("k", 20, 2.0);
        b.cache_hits = 5;
        a.merge(&b);
        assert_eq!(a.loop_bytes, 30);
        assert_eq!(a.per_loop["k"].invocations, 2);
        assert_eq!(a.cache_hits, 5);
    }

    #[test]
    fn hottest_sorts_by_time() {
        let mut m = Metrics::new();
        m.record_loop("cold", 1, 0.1);
        m.record_loop("hot", 1, 9.0);
        let h = m.hottest(1);
        assert_eq!(h[0].0, "hot");
    }
}
