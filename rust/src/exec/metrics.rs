//! Metrics, chief among them the paper's §5.1 **Average Bandwidth**:
//! per-loop bytes touched (1× for reads or writes, 2× for read+write)
//! divided by per-loop modelled runtime, weighted-averaged over all loops
//! — equivalently, total useful bytes over total loop time.

use super::timeline::{StreamClass, Timeline, TraceEvent};
use crate::obs::Registry;
use std::collections::{BTreeMap, HashMap};

/// Accumulated statistics for one kernel name.
#[derive(Debug, Clone, Default)]
pub struct LoopStat {
    pub invocations: u64,
    pub bytes: u64,
    pub time_s: f64,
}

impl LoopStat {
    pub fn bandwidth_gbs(&self) -> f64 {
        if self.time_s > 0.0 {
            self.bytes as f64 / self.time_s / 1e9
        } else {
            0.0
        }
    }
}

/// Accumulated busy/byte accounting for one timeline resource (stream)
/// — the bottleneck-attribution ledger behind [`Metrics::bound`] and the
/// `--json` `util_*` fields.
#[derive(Debug, Clone)]
pub struct ResourceStat {
    /// Stream class of the resource (fixed at first sight).
    pub class: StreamClass,
    /// Σ event durations on this resource, seconds.
    pub busy_s: f64,
    /// Σ bytes the resource's events moved/touched.
    pub bytes: u64,
    /// Number of events.
    pub events: u64,
}

/// Per-rank statistics of sharded execution (accumulated across chains).
#[derive(Debug, Clone, Copy, Default)]
pub struct RankStat {
    /// Modelled compute makespan of this rank's sub-chains, seconds.
    pub compute_s: f64,
    /// Modelled inter-rank halo-exchange time, seconds.
    pub exchange_s: f64,
    /// Halo bytes this rank received.
    pub exchange_bytes: u64,
    /// §5.1 bytes touched by this rank's loop slices.
    pub loop_bytes: u64,
    /// Modelled loop time of this rank's slices, seconds.
    pub loop_time_s: f64,
}

impl RankStat {
    /// This rank's weighted Average Bandwidth (§5.1), GB/s.
    pub fn average_bandwidth_gbs(&self) -> f64 {
        if self.loop_time_s > 0.0 {
            self.loop_bytes as f64 / self.loop_time_s / 1e9
        } else {
            0.0
        }
    }
}

/// Bottleneck attribution verdict: which stream class the run spent the
/// largest fraction of its wall clock on, or [`Bound::Idle`] when no
/// stream accumulated any busy time at all (nothing ran — e.g. a chain
/// whose datasets were all skipped via §4.1 skip lists).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    /// No resource was ever busy: there is nothing to attribute.
    Idle,
    /// The busiest stream class.
    Stream(StreamClass),
}

impl Bound {
    /// Stable lower-case name for reports and the `--json` record
    /// (`"idle"`, `"compute"`, `"upload"`, ...).
    pub fn name(self) -> &'static str {
        match self {
            Bound::Idle => "idle",
            Bound::Stream(c) => c.name(),
        }
    }
}

/// Simulation-wide metrics sink.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Σ bytes touched by loop bodies (§5.1 accounting).
    pub loop_bytes: u64,
    /// Σ modelled loop runtime, seconds.
    pub loop_time_s: f64,
    /// Wall-clock of the whole simulated schedule (≥ loop time when
    /// transfers don't overlap; < Σ component times when they do).
    pub elapsed_s: f64,
    /// Host→device bytes moved (explicit/unified GPU engines).
    pub h2d_bytes: u64,
    /// Device→host bytes moved.
    pub d2h_bytes: u64,
    /// Device→device bytes (tile edge copies).
    pub d2d_bytes: u64,
    /// Logical bytes minus wire bytes across every codec-equipped link
    /// (see [`crate::codec`]); 0 when no codec is attached.
    pub codec_bytes_saved: u64,
    /// MCDRAM-cache statistics (KNL cache mode).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Unified-memory page faults serviced.
    pub page_faults: u64,
    /// Time spent in (modelled) halo exchanges.
    pub halo_time_s: f64,
    /// Number of halo exchanges performed.
    pub halo_exchanges: u64,
    /// Number of loop chains executed.
    pub chains: u64,
    /// Steps executed inside temporally fused super-chains
    /// ([`crate::program::Session::replay_fused`]); 0 when fusion is
    /// off. A run of `n` steps at fusion depth `k` counts
    /// `k * (n / k)` here, the `n % k` tail replaying unfused.
    pub fused_steps: u64,
    /// Number of tiles executed (0 if untiled).
    pub tiles: u64,
    /// Auto-tuner: cost-model evaluations spent (0 when tuning is off).
    pub tune_evals: u64,
    /// Auto-tuner: chains whose plan came from the tuned-plan cache.
    pub tune_cache_hits: u64,
    /// Auto-tuner: Σ modelled (cold-engine) chain time of the chosen
    /// plans, seconds.
    pub tuned_model_s: f64,
    /// Auto-tuner: Σ modelled chain time of the `HBM/3` heuristic plans
    /// — per chain, `tuned_model_s` never exceeds this.
    pub heuristic_model_s: f64,
    /// Chain analyses computed (or adopted from a frozen Program) by
    /// this run. The legacy eager path re-analyses at every flush, so it
    /// counts one per non-empty chain; a replayed Session counts one per
    /// *distinct* chain shape.
    pub analysis_builds: u64,
    /// Chain executions that reused a cached analysis instead of
    /// re-running the dependency/footprint/skew computation.
    pub analysis_reuse_hits: u64,
    /// Host seconds spent freezing the Program (declaration validation +
    /// per-chain analysis), charged once per Session.
    pub program_freeze_s: f64,
    /// Name of the numeric executor backing the run (`"native"`,
    /// `"vector"`, ...); empty when no Session was involved.
    pub exec_backend: String,
    /// Distinct kernel IRs the frozen Program compiled to vector row
    /// plans (a per-Session constant, like `program_freeze_s`).
    pub kir_kernels_compiled: u64,
    /// Loop executions the vector backend ran through the closure
    /// fallback instead of a compiled row plan (0 on the native
    /// backend).
    pub kir_fallback_loops: u64,
    /// Per-kernel-name breakdown.
    pub per_loop: HashMap<String, LoopStat>,
    /// Per-rank breakdown of sharded execution (empty when unsharded).
    pub per_rank: Vec<RankStat>,
    /// Per-timeline-resource busy/byte accounting (bottleneck
    /// attribution). BTreeMap for deterministic report ordering.
    pub per_resource: BTreeMap<String, ResourceStat>,
    /// Observability registry: counters, gauges and log-linear
    /// histograms of modelled quantities (per-loop timings, chain
    /// makespans, halo exchanges). Merges exactly like the scalar
    /// fields, so sweep cells and sharded ranks fold together.
    pub obs: Registry,
    /// Lifecycle spans recorded by the run's thread (captured from
    /// [`crate::obs::span_stats`] by the bench/CLI drivers — spans are
    /// thread-local and do not live on this sink).
    pub spans_recorded: u64,
    /// Deepest span nesting observed (freeze → replay → chain → tile).
    pub span_max_depth: u64,
    /// Recorded timeline events (`Some` once tracing is enabled; the
    /// `--trace` Chrome-trace export renders these).
    trace: Option<Vec<TraceEvent>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one loop execution (possibly one tile's slice of it).
    pub fn record_loop(&mut self, name: &str, bytes: u64, time_s: f64) {
        self.loop_bytes += bytes;
        self.loop_time_s += time_s;
        self.obs.record("loop_time_s", time_s);
        let st = self.per_loop.entry(name.to_string()).or_default();
        st.invocations += 1;
        st.bytes += bytes;
        st.time_s += time_s;
    }

    // ---- timeline absorption / bottleneck attribution -------------------

    /// Start collecting timeline events for trace export. Idempotent.
    pub fn enable_trace(&mut self) {
        if self.trace.is_none() {
            self.trace = Some(Vec::new());
        }
    }

    /// Whether engines should log individual events (beyond the always-on
    /// per-resource busy accounting).
    pub fn trace_enabled(&self) -> bool {
        self.trace.is_some()
    }

    /// The collected events (empty when tracing is off).
    pub fn trace_events(&self) -> &[TraceEvent] {
        self.trace.as_deref().unwrap_or(&[])
    }

    /// Drain the collected events, keeping tracing enabled.
    pub fn take_trace_events(&mut self) -> Vec<TraceEvent> {
        match &mut self.trace {
            Some(evs) => std::mem::take(evs),
            None => Vec::new(),
        }
    }

    /// Append one event on the run's global clock (callers rebase chain-
    /// local times themselves; [`Metrics::absorb_timeline`] does this for
    /// whole timelines). No-op when tracing is off.
    pub fn push_trace_event(&mut self, ev: TraceEvent) {
        if let Some(evs) = &mut self.trace {
            evs.push(ev);
        }
    }

    /// Lay another run's collected events onto this sink's clock: shift
    /// each event by `offset_s` and prefix its resource name, so many
    /// per-request engine timelines interleave on one virtual serving
    /// clock without colliding on stream names. No-op when tracing is
    /// off here.
    pub fn absorb_trace_events(&mut self, events: &[TraceEvent], offset_s: f64, prefix: &str) {
        let Some(evs) = &mut self.trace else {
            return;
        };
        for ev in events {
            let mut ev = ev.clone();
            ev.resource = format!("{prefix}{}", ev.resource);
            ev.start_s += offset_s;
            ev.end_s += offset_s;
            evs.push(ev);
        }
    }

    /// Fold one resource's accounting into the attribution ledger (the
    /// class of the first sighting of a name sticks).
    pub fn record_stream(
        &mut self,
        name: &str,
        class: StreamClass,
        busy_s: f64,
        bytes: u64,
        events: u64,
    ) {
        let st = self
            .per_resource
            .entry(name.to_string())
            .or_insert(ResourceStat {
                class,
                busy_s: 0.0,
                bytes: 0,
                events: 0,
            });
        st.busy_s += busy_s;
        st.bytes += bytes;
        st.events += events;
    }

    /// Take the per-resource ledger (the sharded engine re-namespaces
    /// its ranks' inner streams through this).
    pub fn take_per_resource(&mut self) -> BTreeMap<String, ResourceStat> {
        std::mem::take(&mut self.per_resource)
    }

    /// Fold a finished chain timeline into this sink: advance the wall
    /// clock by its makespan, accumulate per-resource busy time, and —
    /// when tracing — rebase and collect its events onto the run clock.
    pub fn absorb_timeline(&mut self, mut tl: Timeline) {
        let t0 = self.elapsed_s;
        for (name, class, busy_s, bytes, events) in tl.resource_stats() {
            if events == 0 && busy_s == 0.0 {
                continue;
            }
            let st = self
                .per_resource
                .entry(name.to_string())
                .or_insert(ResourceStat {
                    class,
                    busy_s: 0.0,
                    bytes: 0,
                    events: 0,
                });
            st.busy_s += busy_s;
            st.bytes += bytes;
            st.events += events;
        }
        if let Some(sink) = &mut self.trace {
            for mut ev in tl.take_events() {
                ev.start_s += t0;
                ev.end_s += t0;
                sink.push(ev);
            }
        }
        self.obs.record("chain_makespan_s", tl.makespan());
        self.elapsed_s += tl.makespan();
    }

    /// Quantile point estimates for one registry histogram: `None` when
    /// the series was never recorded, otherwise one (conservative upper
    /// bound) value per requested quantile. The fleet-simulator p50/p99
    /// API (ROADMAP #4).
    pub fn histogram_quantiles(&self, name: &str, qs: &[f64]) -> Option<Vec<f64>> {
        let h = self.obs.histogram(name)?;
        qs.iter().map(|&q| h.quantile(q)).collect()
    }

    /// Utilisation of one stream class over the whole run, in `[0, 1]`:
    /// the busiest single resource of that class, as a fraction of wall
    /// time. The *max*, not the sum — concurrent per-rank streams of one
    /// class would otherwise report >1; the bottleneck question is "did
    /// any instance of this stream run out of headroom". Internally-
    /// pipelined streams (the unified engine's bulk-prefetch migration
    /// stream schedules overlapping events) can accumulate busy time
    /// beyond their wall share; they saturate at 1.0 — fully
    /// oversubscribed — keeping the documented fraction contract.
    pub fn stream_util(&self, class: StreamClass) -> f64 {
        if self.elapsed_s <= 0.0 {
            return 0.0;
        }
        self.per_resource
            .values()
            .filter(|st| st.class == class)
            .fold(0.0f64, |m, st| m.max(st.busy_s / self.elapsed_s))
            .min(1.0)
    }

    /// Utilisation of one named resource over the whole run, in
    /// `[0, 1]` (saturating like [`Metrics::stream_util`]); `None` when
    /// the resource never ran or no wall time elapsed. Useful for
    /// reading the tiered engine's `{tier}:upload` / `{tier}:download`
    /// streams individually.
    pub fn resource_util(&self, name: &str) -> Option<f64> {
        if self.elapsed_s <= 0.0 {
            return None;
        }
        self.per_resource
            .get(name)
            .map(|st| (st.busy_s / self.elapsed_s).min(1.0))
    }

    /// The single busiest resource (name, utilisation) — the
    /// finer-grained sibling of [`Metrics::bound`], naming the exact
    /// stream (e.g. `host:upload` on a three-tier run, `r3:link` when
    /// sharded) instead of its class.
    pub fn bound_resource(&self) -> Option<(&str, f64)> {
        if self.elapsed_s <= 0.0 {
            return None;
        }
        self.per_resource
            .iter()
            .map(|(k, st)| (k.as_str(), (st.busy_s / self.elapsed_s).min(1.0)))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            // a ledger of never-busy streams (every dataset skipped via
            // §4.1 skip lists) has no bottleneck — don't name an
            // arbitrary idle stream
            .filter(|&(_, u)| u > 0.0)
    }

    /// Bottleneck attribution: the stream class with the highest
    /// utilisation, or [`Bound::Idle`] when nothing accumulated busy
    /// time (empty ledger, or an all-skipped chain). A compute-bound
    /// run reports `Stream(Compute)`; a PCIe-upload-bound streaming run
    /// `Stream(Upload)`.
    pub fn bound(&self) -> Bound {
        let mut bound = Bound::Idle;
        let mut top = 0.0f64;
        for class in StreamClass::ALL {
            let u = self.stream_util(class);
            // strictly greater: ties keep the earlier (compute-first)
            // class, and a bound requires some utilisation at all
            if u > top {
                top = u;
                bound = Bound::Stream(class);
            }
        }
        bound
    }

    /// The headline metric: weighted Average Bandwidth in GB/s.
    pub fn average_bandwidth_gbs(&self) -> f64 {
        if self.loop_time_s > 0.0 {
            self.loop_bytes as f64 / self.loop_time_s / 1e9
        } else {
            0.0
        }
    }

    /// Average bandwidth against *wall* time (includes non-overlapped
    /// transfer and halo time) — what problem-scaling figures plot.
    pub fn effective_bandwidth_gbs(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.loop_bytes as f64 / self.elapsed_s / 1e9
        } else {
            0.0
        }
    }

    /// Modelled speedup of tuned plans over the `HBM/3` heuristic:
    /// Σ heuristic model time / Σ tuned model time. 1.0 when tuning is
    /// off (or everywhere chose the heuristic); never below 1.0 by the
    /// tuner's never-worse guarantee.
    pub fn tune_model_speedup(&self) -> f64 {
        if self.tuned_model_s > 0.0 {
            self.heuristic_model_s / self.tuned_model_s
        } else {
            1.0
        }
    }

    /// MCDRAM cache hit rate in `[0, 1]` (1.0 when no cache modelled).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            1.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Merge another metrics block into this one (used by sweep drivers).
    pub fn merge(&mut self, other: &Metrics) {
        self.loop_bytes += other.loop_bytes;
        self.loop_time_s += other.loop_time_s;
        self.elapsed_s += other.elapsed_s;
        self.h2d_bytes += other.h2d_bytes;
        self.d2h_bytes += other.d2h_bytes;
        self.d2d_bytes += other.d2d_bytes;
        self.codec_bytes_saved += other.codec_bytes_saved;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.page_faults += other.page_faults;
        self.halo_time_s += other.halo_time_s;
        self.halo_exchanges += other.halo_exchanges;
        self.chains += other.chains;
        self.fused_steps += other.fused_steps;
        self.tiles += other.tiles;
        self.tune_evals += other.tune_evals;
        self.tune_cache_hits += other.tune_cache_hits;
        self.tuned_model_s += other.tuned_model_s;
        self.heuristic_model_s += other.heuristic_model_s;
        self.analysis_builds += other.analysis_builds;
        self.analysis_reuse_hits += other.analysis_reuse_hits;
        self.program_freeze_s += other.program_freeze_s;
        if self.exec_backend.is_empty() {
            self.exec_backend = other.exec_backend.clone();
        }
        self.kir_kernels_compiled += other.kir_kernels_compiled;
        self.kir_fallback_loops += other.kir_fallback_loops;
        for (k, v) in &other.per_loop {
            let st = self.per_loop.entry(k.clone()).or_default();
            st.invocations += v.invocations;
            st.bytes += v.bytes;
            st.time_s += v.time_s;
        }
        if self.per_rank.len() < other.per_rank.len() {
            self.per_rank.resize(other.per_rank.len(), RankStat::default());
        }
        for (r, v) in other.per_rank.iter().enumerate() {
            let st = &mut self.per_rank[r];
            st.compute_s += v.compute_s;
            st.exchange_s += v.exchange_s;
            st.exchange_bytes += v.exchange_bytes;
            st.loop_bytes += v.loop_bytes;
            st.loop_time_s += v.loop_time_s;
        }
        for (name, st) in &other.per_resource {
            self.record_stream(name, st.class, st.busy_s, st.bytes, st.events);
        }
        self.obs.merge(&other.obs);
        self.spans_recorded += other.spans_recorded;
        self.span_max_depth = self.span_max_depth.max(other.span_max_depth);
        if let Some(theirs) = &other.trace {
            // Event times stay on each source's own clock — sweep cells
            // are independent runs, so a merged trace is per-cell.
            self.enable_trace();
            if let Some(ours) = &mut self.trace {
                ours.extend(theirs.iter().cloned());
            }
        }
    }

    /// Kernel names sorted by time share, descending — profiling report.
    pub fn hottest(&self, n: usize) -> Vec<(String, LoopStat)> {
        let mut v: Vec<_> = self
            .per_loop
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| b.1.time_s.total_cmp(&a.1.time_s));
        v.truncate(n);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_bandwidth_is_weighted() {
        let mut m = Metrics::new();
        // 100 GB in 1 s + 100 GB in 3 s → 200 GB / 4 s = 50 GB/s,
        // NOT the arithmetic mean of 100 and 33.3.
        m.record_loop("a", 100_000_000_000, 1.0);
        m.record_loop("b", 100_000_000_000, 3.0);
        assert!((m.average_bandwidth_gbs() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn hit_rate_defaults_to_one() {
        let m = Metrics::new();
        assert_eq!(m.cache_hit_rate(), 1.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics::new();
        a.record_loop("k", 10, 1.0);
        let mut b = Metrics::new();
        b.record_loop("k", 20, 2.0);
        b.cache_hits = 5;
        a.merge(&b);
        assert_eq!(a.loop_bytes, 30);
        assert_eq!(a.per_loop["k"].invocations, 2);
        assert_eq!(a.cache_hits, 5);
    }

    #[test]
    fn absorb_timeline_attributes_and_advances_clock() {
        use crate::exec::timeline::{EventKind, Timeline};
        let mut m = Metrics::new();
        m.enable_trace();
        m.elapsed_s = 1.0;
        let mut tl = Timeline::new(m.trace_enabled());
        let c = tl.resource("compute", StreamClass::Compute);
        let u = tl.resource("upload", StreamClass::Upload);
        tl.push(u, EventKind::Upload, "t0", 0.5, 100);
        tl.wait(c, u);
        tl.push(c, EventKind::Compute, "k", 2.0, 400);
        m.absorb_timeline(tl);
        assert_eq!(m.elapsed_s, 3.5);
        assert_eq!(m.per_resource["compute"].busy_s, 2.0);
        assert_eq!(m.per_resource["upload"].bytes, 100);
        // events rebased onto the run clock (chain started at 1.0)
        let evs = m.trace_events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].start_s, 1.0);
        assert_eq!(evs[1].start_s, 1.5);
        // attribution: compute is the busiest stream
        assert_eq!(m.bound(), Bound::Stream(StreamClass::Compute));
        assert_eq!(m.bound().name(), "compute");
        // the absorbed chain's makespan landed in the registry
        assert_eq!(m.obs.histogram("chain_makespan_s").unwrap().count(), 1);
        assert!((m.stream_util(StreamClass::Compute) - 2.0 / 3.5).abs() < 1e-12);
        assert!((m.stream_util(StreamClass::Upload) - 0.5 / 3.5).abs() < 1e-12);
        assert_eq!(m.stream_util(StreamClass::Download), 0.0);
    }

    #[test]
    fn bound_is_idle_when_nothing_ran() {
        let m = Metrics::new();
        assert_eq!(m.bound(), Bound::Idle);
        assert_eq!(m.bound().name(), "idle");
        assert!(!m.trace_enabled());
        assert!(m.trace_events().is_empty());
    }

    #[test]
    fn all_skipped_chain_reports_idle_not_an_arbitrary_stream() {
        // §4.1 skip lists can skip every dataset of a chain: streams get
        // registered on the timeline but never accumulate busy time.
        // Attribution must say "idle", not crown the first-named stream.
        let mut m = Metrics::new();
        m.elapsed_s = 1.0;
        m.record_stream("compute", StreamClass::Compute, 0.0, 0, 0);
        m.record_stream("upload", StreamClass::Upload, 0.0, 0, 0);
        assert_eq!(m.bound(), Bound::Idle);
        assert_eq!(m.bound().name(), "idle");
        assert_eq!(m.bound_resource(), None, "no idle stream gets named");
        // the moment anything runs, attribution resumes
        m.record_stream("upload", StreamClass::Upload, 0.25, 64, 1);
        assert_eq!(m.bound(), Bound::Stream(StreamClass::Upload));
        assert_eq!(m.bound_resource(), Some(("upload", 0.25)));
    }

    #[test]
    fn merge_folds_resources_and_traces() {
        use crate::exec::timeline::{EventKind, Timeline};
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        b.enable_trace();
        let mut tl = Timeline::new(true);
        let c = tl.resource("compute", StreamClass::Compute);
        tl.push(c, EventKind::Compute, "k", 1.0, 8);
        b.absorb_timeline(tl);
        a.record_stream("compute", StreamClass::Compute, 0.5, 4, 1);
        a.merge(&b);
        assert_eq!(a.per_resource["compute"].busy_s, 1.5);
        assert_eq!(a.per_resource["compute"].events, 2);
        assert_eq!(a.trace_events().len(), 1);
    }

    #[test]
    fn stream_util_takes_the_busiest_instance_per_class() {
        let mut m = Metrics::new();
        m.elapsed_s = 10.0;
        m.record_stream("r0:compute", StreamClass::Compute, 9.0, 0, 1);
        m.record_stream("r1:compute", StreamClass::Compute, 4.0, 0, 1);
        assert!((m.stream_util(StreamClass::Compute) - 0.9).abs() < 1e-12);
        assert_eq!(m.bound(), Bound::Stream(StreamClass::Compute));
    }

    #[test]
    fn registry_and_span_stats_ride_along_on_merge() {
        let mut a = Metrics::new();
        a.record_loop("k", 8, 0.5);
        a.spans_recorded = 3;
        a.span_max_depth = 2;
        let mut b = Metrics::new();
        b.record_loop("k", 8, 1.5);
        b.obs.counter_add("tiles_done", 4);
        b.spans_recorded = 5;
        b.span_max_depth = 4;
        a.merge(&b);
        assert_eq!(a.obs.histogram("loop_time_s").unwrap().count(), 2);
        assert_eq!(a.obs.counter("tiles_done"), 4);
        assert_eq!(a.spans_recorded, 8);
        assert_eq!(a.span_max_depth, 4);
        let qs = a.histogram_quantiles("loop_time_s", &[0.5, 0.99]).unwrap();
        assert_eq!(qs.len(), 2);
        assert!(qs[0] <= qs[1]);
        assert!(a.histogram_quantiles("absent", &[0.5]).is_none());
    }

    #[test]
    fn hottest_sorts_by_time() {
        let mut m = Metrics::new();
        m.record_loop("cold", 1, 0.1);
        m.record_loop("hot", 1, 9.0);
        let h = m.hottest(1);
        assert_eq!(h[0].0, "hot");
    }
}
