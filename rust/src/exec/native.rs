//! The native executor: runs kernel bodies point-by-point over iteration
//! ranges — the role OPS's generated C/CUDA code plays.

use super::Executor;
use crate::ops::kernel::{ArgView, Ctx};
use crate::ops::{Arg, DataStore, Dataset, LoopInst, Range3, Reduction};

/// Runs loop bodies directly in Rust.
#[derive(Debug, Default)]
pub struct NativeExecutor {
    /// Loop executions performed (diagnostics).
    pub loops_run: u64,
    /// Iteration points executed (diagnostics).
    pub points_run: u64,
}

impl NativeExecutor {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Executor for NativeExecutor {
    fn run_loop(
        &mut self,
        l: &LoopInst,
        range: Range3,
        datasets: &[Dataset],
        store: &mut DataStore,
        reds: &mut [Reduction],
    ) {
        run_loop_native(l, range, datasets, store, reds);
        self.loops_run += 1;
        self.points_run += crate::ops::parloop::range_points(&range);
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Per-loop execution tables: argument views positioned at the range
/// origin, the flat global-constant table and the local reduction slots.
/// Shared by the native and vector executors so both resolve arguments
/// identically.
pub(crate) struct LoopSetup {
    pub views: Vec<ArgView>,
    pub consts: Vec<f64>,
    /// Local slot → global `ReductionId` index.
    pub red_slots: Vec<usize>,
    /// Per-loop partial values, starting at the operator identity.
    pub red_vals: Vec<f64>,
}

pub(crate) fn loop_setup(
    l: &LoopInst,
    range: &Range3,
    datasets: &[Dataset],
    store: &mut DataStore,
) -> LoopSetup {
    let (x0, _) = range[0];
    let (y0, _) = range[1];
    let (z0, _) = range[2];
    let mut views: Vec<ArgView> = Vec::with_capacity(l.args.len());
    let mut red_slots: Vec<usize> = Vec::new();
    let mut red_vals: Vec<f64> = Vec::new();
    let mut consts: Vec<f64> = Vec::new();

    for a in &l.args {
        match a {
            Arg::Dat { dat, acc, .. } => {
                #[cfg(not(debug_assertions))]
                let _ = acc;
                let ds = &datasets[dat.0 as usize];
                let (base, _len) = store.raw(*dat);
                let strides = ds.strides();
                let origin = ds.offset([x0, y0, z0]);
                views.push(ArgView {
                    ptr: unsafe { base.offset(origin) },
                    strides,
                    #[cfg(debug_assertions)]
                    lo: base as *const f64,
                    #[cfg(debug_assertions)]
                    hi: unsafe { base.add(_len) as *const f64 },
                    #[cfg(debug_assertions)]
                    acc: *acc,
                });
            }
            Arg::GblRed { red, op } => {
                red_slots.push(red.0 as usize);
                red_vals.push(op.identity());
            }
            Arg::GblConst { values } => consts.extend_from_slice(values),
            Arg::Idx => {}
        }
    }

    LoopSetup {
        views,
        consts,
        red_slots,
        red_vals,
    }
}

/// Fold per-loop reduction slots into the global reduction table.
pub(crate) fn fold_reductions(red_slots: &[usize], red_vals: &[f64], reds: &mut [Reduction]) {
    for (slot, &rid) in red_slots.iter().enumerate() {
        let r = &mut reds[rid];
        r.value = r.op.combine(r.value, red_vals[slot]);
    }
}

/// Free-function core so other executors (PJRT fallback, the vector
/// backend's non-IR path) can reuse it.
pub fn run_loop_native(
    l: &LoopInst,
    range: Range3,
    datasets: &[Dataset],
    store: &mut DataStore,
    reds: &mut [Reduction],
) {
    let (x0, x1) = range[0];
    let (y0, y1) = range[1];
    let (z0, z1) = range[2];
    if x0 >= x1 || y0 >= y1 || z0 >= z1 {
        return;
    }

    let LoopSetup {
        views,
        consts,
        red_slots,
        mut red_vals,
    } = loop_setup(l, &range, datasets, store);

    // Row positioning is incremental: plane views advance by the z
    // stride per plane, row views by the y stride per row — no per-row
    // re-derivation from the range origin.
    let mut plane_views = views;
    for z in z0..z1 {
        let mut row_views = plane_views.clone();
        for y in y0..y1 {
            {
                let mut ctx = Ctx {
                    args: &row_views,
                    red: &mut red_vals,
                    consts: &consts,
                    idx: [x0, y, z],
                    xoff: 0,
                    #[cfg(debug_assertions)]
                    wrote: 0,
                };
                for x in x0..x1 {
                    ctx.idx[0] = x;
                    ctx.xoff = x - x0;
                    #[cfg(debug_assertions)]
                    {
                        ctx.wrote = 0;
                    }
                    (l.kernel)(&mut ctx);
                }
            }
            for v in row_views.iter_mut() {
                v.ptr = unsafe { v.ptr.offset(v.strides[1]) };
            }
        }
        for v in plane_views.iter_mut() {
            v.ptr = unsafe { v.ptr.offset(v.strides[2]) };
        }
    }

    fold_reductions(&red_slots, &red_vals, reds);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::kernel::kernel;
    use crate::ops::stencil::StencilId;
    use crate::ops::{Access, BlockId, DatasetId, RedOp, ReductionId};
    use std::sync::Arc;

    fn dataset(id: u32, size: [usize; 3]) -> Dataset {
        Dataset {
            id: DatasetId(id),
            block: BlockId(0),
            name: format!("d{id}"),
            size,
            halo_lo: [2, 2, 0],
            halo_hi: [2, 2, 0],
            elem_bytes: 8,
        }
    }

    #[test]
    fn write_then_read_with_stencil() {
        let d0 = dataset(0, [8, 8, 1]);
        let d1 = dataset(1, [8, 8, 1]);
        let mut store = DataStore::new();
        store.alloc(&d0);
        store.alloc(&d1);
        let datasets = vec![d0, d1];
        let mut reds: Vec<Reduction> = vec![];

        // loop 1: d0[i,j] = i + 10*j over full padded-interior range
        let l1 = LoopInst {
            name: "init".into(),
            block: BlockId(0),
            range: [(-2, 10), (-2, 10), (0, 1)],
            args: vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)],
            kernel: kernel(|c| {
                let [x, y, _] = c.idx();
                c.w(0, 0, 0, (x + 10 * y) as f64);
            }),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        };
        // loop 2: d1 = laplacian-ish sum of d0 neighbours
        let l2 = LoopInst {
            name: "stencil".into(),
            block: BlockId(0),
            range: [(0, 8), (0, 8), (0, 1)],
            args: vec![
                Arg::dat(DatasetId(0), StencilId(1), Access::Read),
                Arg::dat(DatasetId(1), StencilId(0), Access::Write),
            ],
            kernel: kernel(|c| {
                let v = c.r(0, -1, 0) + c.r(0, 1, 0) + c.r(0, 0, -1) + c.r(0, 0, 1);
                c.w(1, 0, 0, v);
            }),
            kernel_ir: None,
            seq: 1,
            bw_efficiency: 1.0,
        };

        let mut ex = NativeExecutor::new();
        ex.run_loop(&l1, l1.range, &datasets, &mut store, &mut reds);
        ex.run_loop(&l2, l2.range, &datasets, &mut store, &mut reds);

        // check one interior point: neighbours of (3,4)
        let expect = (2 + 40) + (4 + 40) + (3 + 30) + (3 + 50);
        let off = datasets[1].offset([3, 4, 0]) as usize;
        assert_eq!(store.buf(DatasetId(1))[off], expect as f64);
        assert_eq!(ex.loops_run, 2);
    }

    #[test]
    fn reduction_min() {
        let d0 = dataset(0, [4, 4, 1]);
        let mut store = DataStore::new();
        store.alloc(&d0);
        let datasets = vec![d0];
        let mut reds = vec![Reduction::new(ReductionId(0), "m", RedOp::Min)];

        let init = LoopInst {
            name: "init".into(),
            block: BlockId(0),
            range: [(0, 4), (0, 4), (0, 1)],
            args: vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)],
            kernel: kernel(|c| {
                let [x, y, _] = c.idx();
                c.w(0, 0, 0, ((x - 1) * (y - 2)) as f64);
            }),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        };
        let red = LoopInst {
            name: "minred".into(),
            block: BlockId(0),
            range: [(0, 4), (0, 4), (0, 1)],
            args: vec![
                Arg::dat(DatasetId(0), StencilId(0), Access::Read),
                Arg::GblRed {
                    red: ReductionId(0),
                    op: RedOp::Min,
                },
            ],
            kernel: kernel(|c| {
                let v = c.r(0, 0, 0);
                c.red_min(0, v);
            }),
            kernel_ir: None,
            seq: 1,
            bw_efficiency: 1.0,
        };
        let mut ex = NativeExecutor::new();
        ex.run_loop(&init, init.range, &datasets, &mut store, &mut reds);
        ex.run_loop(&red, red.range, &datasets, &mut store, &mut reds);
        // min over (x-1)(y-2) for x,y in 0..4: min is (3-1)*(0-2) = -4? check:
        // values: (x-1) in {-1,0,1,2}, (y-2) in {-2,-1,0,1}; min product = 2*(-2) = -4.
        assert_eq!(reds[0].value, -4.0);
    }

    #[test]
    fn gbl_const_passed_through() {
        let d0 = dataset(0, [2, 2, 1]);
        let mut store = DataStore::new();
        store.alloc(&d0);
        let datasets = vec![d0];
        let mut reds = vec![];
        let l = LoopInst {
            name: "c".into(),
            block: BlockId(0),
            range: [(0, 2), (0, 2), (0, 1)],
            args: vec![
                Arg::dat(DatasetId(0), StencilId(0), Access::Write),
                Arg::GblConst {
                    values: vec![2.5, 4.0],
                },
            ],
            kernel: kernel(|c| {
                let v = c.gbl(0) * c.gbl(1);
                c.w(0, 0, 0, v);
            }),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        };
        let mut ex = NativeExecutor::new();
        ex.run_loop(&l, l.range, &datasets, &mut store, &mut reds);
        let off = datasets[0].offset([1, 1, 0]) as usize;
        assert_eq!(store.buf(DatasetId(0))[off], 10.0);
    }

    #[test]
    fn empty_range_is_noop() {
        let d0 = dataset(0, [4, 4, 1]);
        let mut store = DataStore::new();
        store.alloc(&d0);
        let datasets = vec![d0];
        let mut reds = vec![];
        let called = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let called2 = called.clone();
        let l = LoopInst {
            name: "noop".into(),
            block: BlockId(0),
            range: [(2, 2), (0, 4), (0, 1)],
            args: vec![],
            kernel: kernel(move |_| {
                called2.store(true, std::sync::atomic::Ordering::SeqCst)
            }),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        };
        let mut ex = NativeExecutor::new();
        ex.run_loop(&l, l.range, &datasets, &mut store, &mut reds);
        assert!(!called.load(std::sync::atomic::Ordering::SeqCst));
    }

    /// Write-first data may be read back after the same-point write (the
    /// OPS_WRITE carve-out the debug access check must preserve).
    #[test]
    fn write_first_read_back_after_write_is_allowed() {
        let d0 = dataset(0, [4, 4, 1]);
        let mut store = DataStore::new();
        store.alloc(&d0);
        let datasets = vec![d0];
        let mut reds = vec![];
        let l = LoopInst {
            name: "wf".into(),
            block: BlockId(0),
            range: [(0, 4), (0, 4), (0, 1)],
            args: vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)],
            kernel: kernel(|c| {
                c.w(0, 0, 0, 3.0);
                let v = c.r(0, 0, 0); // read back own write: fine
                c.w(0, 0, 0, v * 2.0);
            }),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        };
        let mut ex = NativeExecutor::new();
        ex.run_loop(&l, l.range, &datasets, &mut store, &mut reds);
        let off = datasets[0].offset([1, 1, 0]) as usize;
        assert_eq!(store.buf(DatasetId(0))[off], 6.0);
    }

    /// Reading a write-first argument *before* writing it observes dead
    /// data — the debug access check must catch it (this used to be a
    /// tautological assert that always passed).
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "reads write-first argument")]
    fn read_before_write_of_write_first_arg_panics() {
        let d0 = dataset(0, [4, 4, 1]);
        let mut store = DataStore::new();
        store.alloc(&d0);
        let datasets = vec![d0];
        let mut reds = vec![];
        let l = LoopInst {
            name: "bad".into(),
            block: BlockId(0),
            range: [(0, 4), (0, 4), (0, 1)],
            args: vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)],
            kernel: kernel(|c| {
                let v = c.r(0, 0, 0); // read of dead write-first data
                c.w(0, 0, 0, v + 1.0);
            }),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        };
        let mut ex = NativeExecutor::new();
        ex.run_loop(&l, l.range, &datasets, &mut store, &mut reds);
    }
}
