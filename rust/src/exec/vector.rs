//! The vector executor: a specialising backend that compiles
//! [`KernelIr`](crate::ops::kir::KernelIr) kernels into statement-major
//! *row programs* — slice-based x-inner loops the autovectoriser can chew
//! on — and falls back to [`run_loop_native`] bit-exactly for everything
//! else.
//!
//! ## Execution model
//!
//! For each (y, z) row of the iteration range, the compiled
//! [`RowPlan`](crate::ops::kir::RowPlan) executes its statements as whole
//! -row passes: a `let` fills the local's row buffer, a `store` fills the
//! destination row (through a temp when the expression reads its own
//! argument), a `reduce` folds an evaluated row into the loop's partial
//! in x order. Because IR compilation rejects kernels that read a
//! *written* argument anywhere but the centre point, statement-major
//! row passes observe exactly the same values as the native executor's
//! point-major order — numerics are bit-identical, which
//! `tests/prop_kir.rs` fuzzes and the app equivalence suites pin.
//!
//! ## Aliasing discipline
//!
//! Row buffers come from four disjoint places: dataset rows (distinct
//! heap allocations per dataset; the loop validator guarantees a written
//! dataset appears exactly once among the args), `let` row buffers,
//! tape registers, and the temp row. A step's destination never aliases
//! its own operands: register destinations are allocated before operand
//! registers are released, `let` destinations only read earlier locals,
//! and in-place stores are routed through the temp row. That invariant
//! is what makes the detached-slice access below sound.

use super::native::{fold_reductions, loop_setup, run_loop_native, LoopSetup};
use super::Executor;
use crate::ops::kernel::ArgView;
use crate::ops::kir::{BinOp, Op, PlanStmt, RowPlan, Step, Tape, UnOp, OUT};
use crate::ops::parloop::range_points;
use crate::ops::{DataStore, Dataset, LoopInst, Range3, RedOp, Reduction};

/// Runs IR-carrying loops through compiled row programs; everything else
/// through [`run_loop_native`].
#[derive(Debug, Default)]
pub struct VectorExecutor {
    /// Loop executions performed (diagnostics).
    pub loops_run: u64,
    /// Iteration points executed (diagnostics).
    pub points_run: u64,
    /// Loops that took the compiled row-program fast path.
    pub vector_loops: u64,
    /// Loops that ran through the closure fallback (no IR, IR outside
    /// the vectorisable subset, or a runtime shape mismatch).
    pub fallback_loops: u64,
    scratch: Scratch,
}

impl VectorExecutor {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Executor for VectorExecutor {
    fn run_loop(
        &mut self,
        l: &LoopInst,
        range: Range3,
        datasets: &[Dataset],
        store: &mut DataStore,
        reds: &mut [Reduction],
    ) {
        self.loops_run += 1;
        self.points_run += range_points(&range);
        if let Some(plan) = l.kernel_ir.as_ref().and_then(|ir| ir.plan()) {
            if run_loop_vector(l, plan, range, datasets, store, reds, &mut self.scratch) {
                self.vector_loops += 1;
                return;
            }
        }
        self.fallback_loops += 1;
        run_loop_native(l, range, datasets, store, reds);
    }

    fn name(&self) -> &'static str {
        "vector"
    }

    fn kir_loop_stats(&self) -> (u64, u64) {
        (self.vector_loops, self.fallback_loops)
    }
}

/// Reusable row buffers, grown per loop and shared across rows.
#[derive(Debug, Default)]
struct Scratch {
    locals: Vec<Vec<f64>>,
    regs: Vec<Vec<f64>>,
    tmp: Vec<f64>,
}

impl Scratch {
    fn ensure(&mut self, plan: &RowPlan, n: usize) {
        if self.locals.len() < plan.n_locals {
            self.locals.resize_with(plan.n_locals, Vec::new);
        }
        for b in self.locals.iter_mut().take(plan.n_locals) {
            if b.len() < n {
                b.resize(n, 0.0);
            }
        }
        if self.regs.len() < plan.n_regs {
            self.regs.resize_with(plan.n_regs, Vec::new);
        }
        for b in self.regs.iter_mut().take(plan.n_regs) {
            if b.len() < n {
                b.resize(n, 0.0);
            }
        }
        if self.tmp.len() < n {
            self.tmp.resize(n, 0.0);
        }
    }
}

/// Run one loop through its row plan. Returns `false` (without touching
/// any data) when the plan's shape does not fit this loop's runtime
/// tables — the caller then falls back to the closure.
fn run_loop_vector(
    l: &LoopInst,
    plan: &RowPlan,
    range: Range3,
    datasets: &[Dataset],
    store: &mut DataStore,
    reds: &mut [Reduction],
    scratch: &mut Scratch,
) -> bool {
    let (x0, x1) = range[0];
    let (y0, y1) = range[1];
    let (z0, z1) = range[2];
    if x0 >= x1 || y0 >= y1 || z0 >= z1 {
        return true;
    }
    let LoopSetup {
        views,
        consts,
        red_slots,
        mut red_vals,
    } = loop_setup(l, &range, datasets, store);
    if plan.n_args > views.len() || plan.n_gbl > consts.len() || plan.n_red > red_vals.len() {
        return false;
    }
    if views.iter().any(|v| v.strides[0] != 1) {
        return false;
    }
    #[cfg(debug_assertions)]
    check_bounds(plan, &views, &range);

    let n = (x1 - x0) as usize;
    scratch.ensure(plan, n);

    let mut plane_views = views;
    for z in z0..z1 {
        let mut row_views = plane_views.clone();
        for y in y0..y1 {
            let env = RowEnv {
                views: &row_views,
                consts: &consts,
                x0,
                y,
                z,
                n,
            };
            run_row(plan, &env, scratch, &mut red_vals);
            for v in row_views.iter_mut() {
                v.ptr = unsafe { v.ptr.offset(v.strides[1]) };
            }
        }
        for v in plane_views.iter_mut() {
            v.ptr = unsafe { v.ptr.offset(v.strides[2]) };
        }
    }

    fold_reductions(&red_slots, &red_vals, reds);
    true
}

/// Debug analogue of `Ctx::addr`'s bounds assert: the row path computes
/// addresses directly, so pre-check every (arg, offset) access over the
/// full range extent before touching memory.
#[cfg(debug_assertions)]
fn check_bounds(plan: &RowPlan, views: &[ArgView], range: &Range3) {
    for &(arg, off) in &plan.accesses {
        let v = &views[arg];
        let first = off[0] as isize
            + off[1] as isize * v.strides[1]
            + off[2] as isize * v.strides[2];
        let last = first
            + (range[0].1 - range[0].0 - 1)
            + (range[1].1 - range[1].0 - 1) * v.strides[1]
            + (range[2].1 - range[2].0 - 1) * v.strides[2];
        let p0 = v.ptr.wrapping_offset(first) as *const f64;
        let p1 = v.ptr.wrapping_offset(last) as *const f64;
        assert!(
            p0 >= v.lo && p1 < v.hi,
            "vector kernel access out of bounds: arg {arg} offset {off:?}"
        );
    }
}

/// Everything a row pass needs: views positioned at the row start
/// `(x0, y, z)`, the constant table and the row geometry.
struct RowEnv<'a> {
    views: &'a [ArgView],
    consts: &'a [f64],
    x0: isize,
    y: isize,
    z: isize,
    n: usize,
}

fn run_row(plan: &RowPlan, env: &RowEnv<'_>, scratch: &mut Scratch, red_vals: &mut [f64]) {
    let Scratch { locals, regs, tmp } = scratch;
    for stmt in &plan.steps {
        match stmt {
            PlanStmt::Let { dst, tape } => {
                // Split so the destination local is exclusive while the
                // tape reads only earlier locals (compile-validated).
                let (done, rest) = locals.split_at_mut(*dst);
                let dstbuf = &mut rest[0][..env.n];
                exec_tape(tape, dstbuf, env, done, regs);
            }
            PlanStmt::Store {
                arg,
                in_place,
                tape,
            } => {
                let row = env.views[*arg].ptr;
                if *in_place {
                    let t = &mut tmp[..env.n];
                    exec_tape(tape, t, env, locals, regs);
                    unsafe { detached_mut(row, env.n) }.copy_from_slice(t);
                } else {
                    // SAFETY: no operand of this tape reads the stored
                    // argument (`in_place` is false) and a written
                    // dataset appears exactly once among the loop args,
                    // so the destination row aliases nothing the tape
                    // reads.
                    let d = unsafe { detached_mut(row, env.n) };
                    exec_tape(tape, d, env, locals, regs);
                }
            }
            PlanStmt::Reduce { slot, op, tape } => {
                let t = &mut tmp[..env.n];
                exec_tape(tape, t, env, locals, regs);
                // Fold in x order with exactly the `Ctx::red_*` scalar
                // semantics (`<`/`>` comparisons, not f64::min/max).
                let acc = &mut red_vals[*slot];
                match op {
                    RedOp::Sum => {
                        for &v in t.iter() {
                            *acc += v;
                        }
                    }
                    RedOp::Min => {
                        for &v in t.iter() {
                            if v < *acc {
                                *acc = v;
                            }
                        }
                    }
                    RedOp::Max => {
                        for &v in t.iter() {
                            if v > *acc {
                                *acc = v;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// A resolved row operand: a contiguous slice or a scalar splat.
#[derive(Clone, Copy)]
enum Src<'a> {
    S(&'a [f64]),
    K(f64),
}

/// SAFETY: caller guarantees `p..p+n` is in bounds and not mutably
/// aliased for the lifetime of the slice (see the module-level aliasing
/// discipline).
unsafe fn detached<'t>(p: *const f64, n: usize) -> &'t [f64] {
    std::slice::from_raw_parts(p, n)
}

/// SAFETY: caller guarantees `p..p+n` is in bounds and exclusively owned
/// for the lifetime of the slice (see the module-level aliasing
/// discipline).
unsafe fn detached_mut<'t>(p: *mut f64, n: usize) -> &'t mut [f64] {
    std::slice::from_raw_parts_mut(p, n)
}

fn resolve<'t>(
    op: &Op,
    env: &RowEnv<'_>,
    locals: &[Vec<f64>],
    regs: &[Vec<f64>],
) -> Src<'t> {
    match op {
        Op::Read { arg, off } => {
            let v = &env.views[*arg as usize];
            let o = off[0] as isize
                + off[1] as isize * v.strides[1]
                + off[2] as isize * v.strides[2];
            // SAFETY: in bounds (debug pre-checked, mirrors Ctx::addr);
            // never mutably aliased within a step per the module
            // invariant.
            Src::S(unsafe { detached(v.ptr.offset(o) as *const f64, env.n) })
        }
        Op::Local(i) => Src::S(unsafe { detached(locals[*i as usize].as_ptr(), env.n) }),
        Op::Reg(r) => Src::S(unsafe { detached(regs[*r as usize].as_ptr(), env.n) }),
        Op::Lit(v) => Src::K(*v),
        Op::Gbl(i) => Src::K(env.consts[*i as usize]),
        Op::IdxY => Src::K(env.y as f64),
        Op::IdxZ => Src::K(env.z as f64),
        Op::IotaX => unreachable!("IotaX only appears as a Mov source"),
    }
}

fn exec_tape(
    tape: &Tape,
    out: &mut [f64],
    env: &RowEnv<'_>,
    locals: &[Vec<f64>],
    regs: &mut [Vec<f64>],
) {
    let n = out.len();
    for step in &tape.steps {
        match step {
            Step::Mov { dst, a } => {
                if matches!(a, Op::IotaX) {
                    let d = dst_slice(*dst, out, regs, n);
                    for (i, v) in d.iter_mut().enumerate() {
                        *v = (env.x0 + i as isize) as f64;
                    }
                } else {
                    let s = resolve(a, env, locals, regs);
                    let d = dst_slice(*dst, out, regs, n);
                    match s {
                        Src::S(x) => d.copy_from_slice(x),
                        Src::K(k) => d.fill(k),
                    }
                }
            }
            Step::Un { op, dst, a } => {
                let a = resolve(a, env, locals, regs);
                let d = dst_slice(*dst, out, regs, n);
                match op {
                    UnOp::Neg => map1(d, a, |v| -v),
                    UnOp::Abs => map1(d, a, |v| v.abs()),
                    UnOp::Sqrt => map1(d, a, |v| v.sqrt()),
                }
            }
            Step::Bin { op, dst, a, b } => {
                let a = resolve(a, env, locals, regs);
                let b = resolve(b, env, locals, regs);
                let d = dst_slice(*dst, out, regs, n);
                bin(*op, d, a, b);
            }
            Step::Sel { dst, c, t, f } => {
                let c = resolve(c, env, locals, regs);
                let t = resolve(t, env, locals, regs);
                let f = resolve(f, env, locals, regs);
                let d = dst_slice(*dst, out, regs, n);
                zip3(d, c, t, f, |c, t, f| if c != 0.0 { t } else { f });
            }
            Step::Sum { dst, terms } => {
                let srcs: Vec<Src<'_>> = terms
                    .iter()
                    .map(|t| resolve(t, env, locals, regs))
                    .collect();
                let d = dst_slice(*dst, out, regs, n);
                sum(d, &srcs);
            }
            Step::Axpy { dst, base, coef, x } => {
                let base = resolve(base, env, locals, regs);
                let Src::K(k) = resolve(coef, env, locals, regs) else {
                    unreachable!("axpy coefficient is a splat by construction")
                };
                let x = resolve(x, env, locals, regs);
                let d = dst_slice(*dst, out, regs, n);
                zip2(d, base, x, move |b, v| b + k * v);
            }
        }
    }
}

/// Resolve a step destination. SAFETY of the register branch: a step's
/// destination register is never one of its own operand registers (the
/// compiler allocates destinations before releasing operands), so the
/// detached exclusive slice aliases none of the operand slices resolved
/// for the same step.
fn dst_slice<'t>(dst: u32, out: &mut [f64], regs: &mut [Vec<f64>], n: usize) -> &'t mut [f64] {
    if dst == OUT {
        unsafe { detached_mut(out.as_mut_ptr(), n) }
    } else {
        unsafe { detached_mut(regs[dst as usize].as_mut_ptr(), n) }
    }
}

#[inline]
fn map1(dst: &mut [f64], a: Src<'_>, f: impl Fn(f64) -> f64) {
    match a {
        Src::S(x) => {
            for (d, &v) in dst.iter_mut().zip(x) {
                *d = f(v);
            }
        }
        Src::K(k) => dst.fill(f(k)),
    }
}

#[inline]
fn zip2(dst: &mut [f64], a: Src<'_>, b: Src<'_>, f: impl Fn(f64, f64) -> f64 + Copy) {
    match (a, b) {
        (Src::S(x), Src::S(y)) => {
            for ((d, &p), &q) in dst.iter_mut().zip(x).zip(y) {
                *d = f(p, q);
            }
        }
        (Src::S(x), Src::K(k)) => {
            for (d, &p) in dst.iter_mut().zip(x) {
                *d = f(p, k);
            }
        }
        (Src::K(k), Src::S(y)) => {
            for (d, &q) in dst.iter_mut().zip(y) {
                *d = f(k, q);
            }
        }
        (Src::K(p), Src::K(q)) => dst.fill(f(p, q)),
    }
}

#[inline]
fn at(s: Src<'_>, i: usize) -> f64 {
    match s {
        Src::S(x) => x[i],
        Src::K(k) => k,
    }
}

#[inline]
fn zip3(
    dst: &mut [f64],
    a: Src<'_>,
    b: Src<'_>,
    c: Src<'_>,
    f: impl Fn(f64, f64, f64) -> f64 + Copy,
) {
    if let (Src::S(x), Src::S(y), Src::S(w)) = (a, b, c) {
        for (((d, &p), &q), &r) in dst.iter_mut().zip(x).zip(y).zip(w) {
            *d = f(p, q, r);
        }
    } else {
        for (i, d) in dst.iter_mut().enumerate() {
            *d = f(at(a, i), at(b, i), at(c, i));
        }
    }
}

fn bin(op: BinOp, d: &mut [f64], a: Src<'_>, b: Src<'_>) {
    match op {
        BinOp::Add => zip2(d, a, b, |x, y| x + y),
        BinOp::Sub => zip2(d, a, b, |x, y| x - y),
        BinOp::Mul => zip2(d, a, b, |x, y| x * y),
        BinOp::Div => zip2(d, a, b, |x, y| x / y),
        BinOp::Min => zip2(d, a, b, |x, y| x.min(y)),
        BinOp::Max => zip2(d, a, b, |x, y| x.max(y)),
        BinOp::Gt => zip2(d, a, b, |x, y| if x > y { 1.0 } else { 0.0 }),
        BinOp::Ge => zip2(d, a, b, |x, y| if x >= y { 1.0 } else { 0.0 }),
        BinOp::Lt => zip2(d, a, b, |x, y| if x < y { 1.0 } else { 0.0 }),
        BinOp::Le => zip2(d, a, b, |x, y| if x <= y { 1.0 } else { 0.0 }),
    }
}

/// Left-associated add chain. Fused arms cover the star-stencil shapes;
/// the generic path accumulates with one vectorised pass per extra term,
/// preserving the association order exactly.
fn sum(dst: &mut [f64], terms: &[Src<'_>]) {
    match terms {
        [Src::S(a), Src::S(b), Src::S(c)] => {
            for (((d, &x), &y), &z) in dst.iter_mut().zip(*a).zip(*b).zip(*c) {
                *d = (x + y) + z;
            }
        }
        [Src::S(a), Src::S(b), Src::S(c), Src::S(e)] => {
            for ((((d, &x), &y), &z), &w) in dst.iter_mut().zip(*a).zip(*b).zip(*c).zip(*e) {
                *d = ((x + y) + z) + w;
            }
        }
        [Src::K(k), Src::S(a), Src::S(b), Src::S(c), Src::S(e)] => {
            let k = *k;
            for ((((d, &x), &y), &z), &w) in dst.iter_mut().zip(*a).zip(*b).zip(*c).zip(*e) {
                *d = (((k + x) + y) + z) + w;
            }
        }
        _ => {
            zip2(dst, terms[0], terms[1], |x, y| x + y);
            for t in &terms[2..] {
                match *t {
                    Src::S(x) => {
                        for (d, &v) in dst.iter_mut().zip(x) {
                            *d += v;
                        }
                    }
                    Src::K(k) => {
                        for d in dst.iter_mut() {
                            *d += k;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::kir::{lit, read, KirBuilder};
    use crate::ops::stencil::StencilId;
    use crate::ops::{Access, Arg, BlockId, DatasetId, KernelIr, RedOp, ReductionId};
    use std::sync::Arc;

    fn dataset(id: u32, size: [usize; 3]) -> Dataset {
        Dataset {
            id: DatasetId(id),
            block: BlockId(0),
            name: format!("d{id}"),
            size,
            halo_lo: [2, 2, 1],
            halo_hi: [2, 2, 1],
            elem_bytes: 8,
        }
    }

    fn seed(store: &mut DataStore, id: DatasetId, scale: f64) {
        for (i, v) in store.buf_mut(id).iter_mut().enumerate() {
            *v = ((i * 2654435761) % 1000) as f64 * scale - 250.0 * scale;
        }
    }

    fn ir_loop(ir: KernelIr, args: Vec<Arg>, range: Range3) -> LoopInst {
        let ir = Arc::new(ir);
        LoopInst {
            name: "t".into(),
            block: BlockId(0),
            range,
            args,
            kernel: ir.to_kernel(),
            kernel_ir: Some(ir),
            seq: 0,
            bw_efficiency: 1.0,
        }
    }

    /// Run the same IR loop through both executors on identically seeded
    /// stores; every written buffer and reduction must be bit-identical.
    fn assert_bit_exact(ir: KernelIr, args: Vec<Arg>, range: Range3, nsets: u32) {
        let datasets: Vec<Dataset> = (0..nsets).map(|i| dataset(i, [6, 5, 3])).collect();
        let mut s_nat = DataStore::new();
        let mut s_vec = DataStore::new();
        for d in &datasets {
            s_nat.alloc(d);
            s_vec.alloc(d);
        }
        for d in &datasets {
            seed(&mut s_nat, d.id, 0.25 + d.id.0 as f64);
            seed(&mut s_vec, d.id, 0.25 + d.id.0 as f64);
        }
        let mut r_nat = vec![
            Reduction::new(ReductionId(0), "a", RedOp::Sum),
            Reduction::new(ReductionId(1), "b", RedOp::Min),
        ];
        let mut r_vec = r_nat.clone();

        let l = ir_loop(ir, args, range);
        assert!(l.kernel_ir.as_ref().unwrap().is_vectorizable());

        let mut nat = crate::exec::NativeExecutor::new();
        nat.run_loop(&l, l.range, &datasets, &mut s_nat, &mut r_nat);
        let mut vec = VectorExecutor::new();
        vec.run_loop(&l, l.range, &datasets, &mut s_vec, &mut r_vec);
        assert_eq!(vec.vector_loops, 1, "must take the row-program path");

        for d in &datasets {
            let a = s_nat.buf(d.id);
            let b = s_vec.buf(d.id);
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "dataset {} differs at {i}: {x} vs {y}",
                    d.id.0
                );
            }
        }
        for (a, b) in r_nat.iter().zip(&r_vec) {
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "reduction differs");
        }
    }

    #[test]
    fn star_stencil_bit_exact() {
        let mut k = KirBuilder::new();
        let l = k.let_(
            read(0, [-1, 0, 0]) + read(0, [1, 0, 0]) + read(0, [0, -1, 0]) + read(0, [0, 1, 0])
                - lit(4.0) * read(0, [0, 0, 0]),
        );
        k.store(1, l * lit(0.3));
        assert_bit_exact(
            k.build(),
            vec![
                Arg::dat(DatasetId(0), StencilId(0), Access::Read),
                Arg::dat(DatasetId(1), StencilId(0), Access::Write),
            ],
            [(0, 6), (0, 5), (0, 3)],
            2,
        );
    }

    #[test]
    fn in_place_axpy_bit_exact() {
        let mut k = KirBuilder::new();
        k.store(0, read(0, [0, 0, 0]) + lit(0.1) * read(1, [0, 0, 0]));
        assert_bit_exact(
            k.build(),
            vec![
                Arg::dat(DatasetId(0), StencilId(0), Access::ReadWrite),
                Arg::dat(DatasetId(1), StencilId(0), Access::Read),
            ],
            [(0, 6), (0, 5), (0, 3)],
            2,
        );
    }

    #[test]
    fn reductions_and_select_bit_exact() {
        let mut k = KirBuilder::new();
        let v = k.let_(read(0, [0, 0, 1]).abs().max(lit(1e-9)));
        k.reduce(0, RedOp::Sum, v.clone().gt(lit(100.0)).select(lit(1.0), v.clone()));
        k.reduce(1, RedOp::Min, lit(1.0) / v);
        assert_bit_exact(
            k.build(),
            vec![
                Arg::dat(DatasetId(0), StencilId(0), Access::Read),
                Arg::GblRed {
                    red: ReductionId(0),
                    op: RedOp::Sum,
                },
                Arg::GblRed {
                    red: ReductionId(1),
                    op: RedOp::Min,
                },
            ],
            [(0, 6), (0, 5), (0, 3)],
            1,
        );
    }

    #[test]
    fn idx_and_gbl_bit_exact() {
        use crate::ops::kir::{gbl, idx};
        let mut k = KirBuilder::new();
        k.store(0, idx(0) * gbl(0) + idx(1) * gbl(1) + idx(2));
        assert_bit_exact(
            k.build(),
            vec![
                Arg::dat(DatasetId(0), StencilId(0), Access::Write),
                Arg::GblConst {
                    values: vec![3.5, -1.25],
                },
            ],
            [(0, 6), (0, 5), (0, 3)],
            1,
        );
    }

    #[test]
    fn sequential_stores_observe_statement_order() {
        // d1 = d0 * 2; d2 = d1 (centre read of the *updated* d1).
        let mut k = KirBuilder::new();
        let v = k.let_(read(0, [0, 0, 0]) * lit(2.0));
        k.store(1, v.clone());
        k.store(2, v + read(1, [0, 0, 0]));
        assert_bit_exact(
            k.build(),
            vec![
                Arg::dat(DatasetId(0), StencilId(0), Access::Read),
                Arg::dat(DatasetId(1), StencilId(0), Access::ReadWrite),
                Arg::dat(DatasetId(2), StencilId(0), Access::Write),
            ],
            [(0, 6), (0, 5), (0, 3)],
            3,
        );
    }

    #[test]
    fn loop_without_ir_falls_back() {
        let d0 = dataset(0, [4, 4, 1]);
        let mut store = DataStore::new();
        store.alloc(&d0);
        let datasets = vec![d0];
        let mut reds = vec![];
        let l = LoopInst {
            name: "plain".into(),
            block: BlockId(0),
            range: [(0, 4), (0, 4), (0, 1)],
            args: vec![Arg::dat(DatasetId(0), StencilId(0), Access::Write)],
            kernel: crate::ops::kernel::kernel(|c| c.w(0, 0, 0, 7.0)),
            kernel_ir: None,
            seq: 0,
            bw_efficiency: 1.0,
        };
        let mut ex = VectorExecutor::new();
        ex.run_loop(&l, l.range, &datasets, &mut store, &mut reds);
        assert_eq!((ex.vector_loops, ex.fallback_loops), (0, 1));
        let off = datasets[0].offset([2, 2, 0]) as usize;
        assert_eq!(store.buf(DatasetId(0))[off], 7.0);
    }
}
