//! The PJRT executor backend: loops whose kernels were AOT-compiled from
//! JAX/Pallas run through XLA; everything else falls back to the native
//! executor.
//!
//! Contract with the artifacts: each program computes a *full sweep* of
//! its kernel over the whole padded arrays (the same elemental function
//! the Rust kernel applies), returning updated arrays. The executor then
//! writes back only the rows inside the requested (possibly
//! tile-restricted) range, which makes the artifact valid for *any*
//! sub-range — exactly the property tiled execution needs.

use super::native::run_loop_native;
use super::Executor;
use crate::ops::{DataStore, Dataset, DatasetId, LoopInst, Range3, Reduction};
use crate::runtime::{ArtifactSpec, LoadedArtifact};
use std::collections::HashMap;

#[cfg_attr(not(feature = "xla"), allow(dead_code))]
struct Bound {
    art: LoadedArtifact,
    inputs: Vec<DatasetId>,
    outputs: Vec<DatasetId>,
}

/// Executor that dispatches registered kernels to PJRT.
pub struct PjrtExecutor {
    bound: HashMap<String, Bound>,
    /// Loops executed through XLA.
    pub pjrt_loops: u64,
    /// Loops that fell back to the native path.
    pub native_loops: u64,
}

impl PjrtExecutor {
    pub fn new() -> Self {
        PjrtExecutor {
            bound: HashMap::new(),
            pjrt_loops: 0,
            native_loops: 0,
        }
    }

    /// Bind an artifact to a kernel name, resolving dataset names against
    /// the declared datasets.
    pub fn register(
        &mut self,
        spec: &ArtifactSpec,
        art: LoadedArtifact,
        datasets: &[Dataset],
    ) -> crate::Result<()> {
        let resolve = |name: &str| -> crate::Result<DatasetId> {
            datasets
                .iter()
                .find(|d| d.name == name)
                .map(|d| d.id)
                .ok_or_else(|| crate::err!("artifact {} references unknown dataset {name}", spec.kernel))
        };
        let inputs = spec
            .inputs
            .iter()
            .map(|n| resolve(n))
            .collect::<crate::Result<Vec<_>>>()?;
        let outputs = spec
            .outputs
            .iter()
            .map(|n| resolve(n))
            .collect::<crate::Result<Vec<_>>>()?;
        // Shape sanity check against the first input dataset.
        if let Some(d0) = inputs.first() {
            let ds = &datasets[d0.0 as usize];
            let padded: Vec<usize> = if ds.padded(2) == 1 {
                vec![ds.padded(1), ds.padded(0)]
            } else {
                vec![ds.padded(2), ds.padded(1), ds.padded(0)]
            };
            crate::ensure!(
                padded == spec.shape,
                "artifact {} compiled for shape {:?} but dataset {} is {:?}",
                spec.kernel,
                spec.shape,
                ds.name,
                padded
            );
        }
        self.bound.insert(
            spec.kernel.clone(),
            Bound {
                art,
                inputs,
                outputs,
            },
        );
        Ok(())
    }

    pub fn registered(&self) -> usize {
        self.bound.len()
    }
}

impl Default for PjrtExecutor {
    fn default() -> Self {
        Self::new()
    }
}

impl Executor for PjrtExecutor {
    fn run_loop(
        &mut self,
        l: &LoopInst,
        range: Range3,
        datasets: &[Dataset],
        store: &mut DataStore,
        reds: &mut [Reduction],
    ) {
        let Some(b) = self.bound.get(&l.name) else {
            self.native_loops += 1;
            run_loop_native(l, range, datasets, store, reds);
            return;
        };
        self.pjrt_loops += 1;

        #[cfg(not(feature = "xla"))]
        {
            let _ = b;
            panic!(
                "kernel {} is bound to a PJRT artifact but ops-oc was built \
                 without the `xla` feature",
                l.name
            );
        }

        #[cfg(feature = "xla")]
        {
            // Gather inputs: full padded buffers as f64 literals.
            let mut lits = Vec::with_capacity(b.inputs.len());
            for &d in &b.inputs {
                let ds = &datasets[d.0 as usize];
                let buf = store.buf(d);
                let lit = xla::Literal::vec1(buf);
                let dims: Vec<i64> = if ds.padded(2) == 1 {
                    vec![ds.padded(1) as i64, ds.padded(0) as i64]
                } else {
                    vec![ds.padded(2) as i64, ds.padded(1) as i64, ds.padded(0) as i64]
                };
                lits.push(lit.reshape(&dims).expect("reshape input literal"));
            }

            let outs = b
                .art
                .run(&lits)
                .unwrap_or_else(|e| panic!("PJRT execution of {} failed: {e:#}", l.name));
            assert_eq!(
                outs.len(),
                b.outputs.len(),
                "artifact {} output arity mismatch",
                l.name
            );

            // Write back only the requested sub-range.
            for (lit, &d) in outs.iter().zip(&b.outputs) {
                let ds = &datasets[d.0 as usize];
                let v: Vec<f64> = lit.to_vec().expect("output literal to_vec");
                assert_eq!(v.len(), ds.alloc_len(), "artifact output size mismatch");
                let buf = store.buf_mut(d);
                let (x0, x1) = range[0];
                for z in range[2].0..range[2].1 {
                    for y in range[1].0..range[1].1 {
                        let off = ds.offset([x0, y, z]) as usize;
                        let n = (x1 - x0) as usize;
                        buf[off..off + n].copy_from_slice(&v[off..off + n]);
                    }
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
