//! Execution layer: numeric *executors* (who computes a loop body) and
//! memory *engines* (in what order tiles run and what the simulated clock
//! says).
//!
//! The split is the heart of the reproduction methodology: **numerics are
//! real** — executors actually run kernel bodies over iteration ranges so
//! tiled and untiled schedules can be compared bit-for-bit — while **time
//! is modelled** by the engines, calibrated against the paper's measured
//! STREAM/baseline numbers (see [`crate::memory::hierarchy`]).

pub mod metrics;
pub mod native;
pub mod pjrt;
pub mod timeline;
pub mod vector;

pub use metrics::{Bound, LoopStat, Metrics, RankStat, ResourceStat};
pub use native::NativeExecutor;
pub use pjrt::PjrtExecutor;
pub use vector::VectorExecutor;
pub use timeline::{
    chrome_trace_json, chrome_trace_json_with_spans, EventKind, StreamClass, Timeline, TraceEvent,
};

use crate::ops::{DataStore, Dataset, LoopInst, Range3, Reduction, Stencil};

/// Which numeric executor a [`crate::program::Session`] builds — the
/// `--exec` seam. Numerics are bit-identical either way; only the loop
/// body machinery differs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecBackend {
    /// Point-by-point closure execution ([`NativeExecutor`]).
    #[default]
    Native,
    /// Compiled kernel-IR row programs with closure fallback
    /// ([`VectorExecutor`]).
    Vector,
}

impl ExecBackend {
    /// Parse a `--exec` flag value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "native" => Some(ExecBackend::Native),
            "vector" => Some(ExecBackend::Vector),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExecBackend::Native => "native",
            ExecBackend::Vector => "vector",
        }
    }
}

/// Everything an engine needs to run a chain: dataset/stencil metadata,
/// the canonical data store, reduction slots and the metrics sink.
pub struct World<'a> {
    pub datasets: &'a [Dataset],
    pub stencils: &'a [Stencil],
    pub store: &'a mut DataStore,
    pub reds: &'a mut [Reduction],
    pub metrics: &'a mut Metrics,
    pub exec: &'a mut dyn Executor,
}

/// A numeric executor: runs one loop body over a (possibly tiled) range.
pub trait Executor {
    /// Execute `l`'s kernel over `range` (which may be a tile-restricted
    /// sub-range of `l.range`).
    fn run_loop(
        &mut self,
        l: &LoopInst,
        range: Range3,
        datasets: &[Dataset],
        store: &mut DataStore,
        reds: &mut [Reduction],
    );

    /// Executor name for reports.
    fn name(&self) -> &'static str;

    /// `(vector_loops, fallback_loops)` counters for executors that
    /// specialise kernel IR; everything else reports zeros.
    fn kir_loop_stats(&self) -> (u64, u64) {
        (0, 0)
    }
}

/// Executor that runs nothing. Used wherever a chain must be *priced*
/// without touching data: the sharded engine's per-rank timing replay
/// and the auto-tuner's candidate scoring both drive engines through
/// this so loop bodies execute exactly once, in the real numerics pass.
pub struct NullExecutor;

impl Executor for NullExecutor {
    fn run_loop(
        &mut self,
        _l: &LoopInst,
        _range: Range3,
        _datasets: &[Dataset],
        _store: &mut DataStore,
        _reds: &mut [Reduction],
    ) {
    }

    fn name(&self) -> &'static str {
        "null"
    }
}

/// A memory engine: executes a full lazily-collected loop chain in some
/// legal order while advancing the simulated clock and metrics.
pub trait Engine {
    /// Run the chain. `cyclic_phase` is the §4.1 flag the application sets
    /// once its regular cyclic execution pattern begins (enables the
    /// unsafe skip-download-of-write-first-data optimisation on GPU
    /// engines).
    fn run_chain(&mut self, chain: &[LoopInst], world: &mut World<'_>, cyclic_phase: bool);

    /// Run the chain with a precomputed
    /// [`ChainAnalysis`](crate::tiling::analysis::ChainAnalysis) (the
    /// record-once/replay-many path: a frozen
    /// [`crate::program::Program`] chain, or a
    /// [`crate::program::Session`]'s memoised dynamic analysis).
    ///
    /// The default ignores the analysis and falls back to
    /// [`Engine::run_chain`] — correct for engines that don't analyse
    /// chains (flat memory). Tiling engines override it to skip the
    /// per-flush dependency/footprint recomputation; either way the
    /// schedule, and therefore the numerics, are identical.
    fn run_chain_analyzed(
        &mut self,
        chain: &[LoopInst],
        analysis: Option<&crate::tiling::analysis::ChainAnalysis>,
        world: &mut World<'_>,
        cyclic_phase: bool,
    ) {
        let _ = analysis;
        self.run_chain(chain, world, cyclic_phase);
    }

    /// Reset transient *schedule-position* state carried across chains —
    /// e.g. the GPU streaming engine's speculative prefetch credit.
    /// Called when a [`crate::program::Session`] rebinds an engine, so a
    /// pre-used engine cannot smuggle overlap credit from chains the new
    /// session never ran. Deliberately does **not** touch modelled
    /// hardware warmth (KNL cache contents, unified-memory residency):
    /// those model device state, not schedule position. Default: no-op.
    fn reset_transient(&mut self) {}

    /// Human-readable configuration string for reports.
    fn describe(&self) -> String;

    /// Whether the modelled configuration can hold the problem at all
    /// (flat-MCDRAM and non-oversubscribed GPU baselines refuse problems
    /// larger than fast memory — the paper reports segfaults/OOM there).
    fn fits(&self, _problem_bytes: u64) -> bool {
        true
    }
}
