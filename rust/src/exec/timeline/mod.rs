//! Deterministic discrete-event timeline — the shared scheduling
//! substrate under every memory engine.
//!
//! Before this subsystem existed each engine modelled its clock with
//! bespoke closed-form float arithmetic (`gpu_explicit` hand-threaded
//! three stream cursors, `sharded` approximated comm/compute overlap
//! independently). A [`Timeline`] replaces that per-engine clock math
//! with one event graph:
//!
//! * **Resources** are named execution streams (`compute`, `upload`,
//!   `download`, `mcdram`, `ddr4`, `migration`, `halo`, per-rank
//!   `r3:link`, …). Each carries a monotone *cursor* — the time at
//!   which it next becomes free — plus busy/byte/event accounting.
//! * **Events** occupy one resource for a duration, starting no earlier
//!   than the resource's cursor and any explicit dependency
//!   ([`Timeline::push_at`]). Cross-stream waits (`cudaStreamWaitEvent`,
//!   a loop waiting on a halo exchange) are [`Timeline::wait`] /
//!   [`Timeline::wait_until`] edges.
//! * The **makespan** ([`Timeline::makespan`]) is the latest cursor —
//!   the modelled wall clock of the chain. Engines fold a finished
//!   timeline into the metrics sink with
//!   [`crate::exec::Metrics::absorb_timeline`], which advances
//!   `elapsed_s`, accumulates per-resource busy time (the bottleneck
//!   attribution behind the `--json` `bound`/`util_*` fields) and, when
//!   tracing is enabled, collects every event for the `--trace`
//!   Chrome-trace export ([`chrome_trace_json`]).
//!
//! The cursor arithmetic is intentionally the *same* float operations
//! the old closed forms performed (`push` adds, `wait` maxes), so
//! rebuilding an engine on the timeline reproduces its legacy modelled
//! clock exactly; the equivalence suites (`program_equivalence`,
//! `tiling_equivalence`, `sharding_equivalence`) pin that.
//!
//! Determinism: a timeline is a pure fold over the sequence of calls —
//! no host clocks, no hashing iteration order — so identical call
//! sequences give bit-identical makespans (property-tested in
//! `tests/prop_timeline.rs`).

/// Coarse stream classification for bottleneck attribution. Every
/// resource belongs to one class; the `--json` record reports one
/// utilisation figure per class and names the busiest class as `bound`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum StreamClass {
    /// Kernel execution (and device-side copies that ride the compute
    /// stream): GPU stream 0, KNL MCDRAM-side time.
    Compute,
    /// Traffic *into* fast memory: H2D uploads, unified-memory faults
    /// and prefetches, KNL DDR4 cache-fill traffic.
    Upload,
    /// Traffic *out of* fast memory: D2H downloads.
    Download,
    /// Inter-rank / inter-process communication: MPI halo exchanges,
    /// the sharded engine's interconnect links.
    Exchange,
    /// Link-codec kernels: compress/decompress time on a tier boundary
    /// or interconnect codec (see [`crate::codec`]). Last in `ALL` so
    /// the earlier classes keep winning `bound()` ties.
    Codec,
}

impl StreamClass {
    pub const ALL: [StreamClass; 5] = [
        StreamClass::Compute,
        StreamClass::Upload,
        StreamClass::Download,
        StreamClass::Exchange,
        StreamClass::Codec,
    ];

    pub fn name(self) -> &'static str {
        match self {
            StreamClass::Compute => "compute",
            StreamClass::Upload => "upload",
            StreamClass::Download => "download",
            StreamClass::Exchange => "exchange",
            StreamClass::Codec => "codec",
        }
    }
}

/// What one event did — the Chrome-trace category.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Kernel execution over a tile / loop range.
    Compute,
    /// Device-device edge copy between tile slots.
    EdgeCopy,
    /// Host→device tile upload (explicit streaming).
    Upload,
    /// Device→host tile download.
    Download,
    /// Unified-memory on-demand fault migration.
    Fault,
    /// Unified-memory bulk prefetch.
    Prefetch,
    /// MCDRAM-cache fill / writeback traffic on the DDR4 side.
    CacheFill,
    /// Intra-node MPI halo exchange.
    Halo,
    /// Inter-rank halo exchange over the modelled interconnect.
    Exchange,
    /// Codec compression kernel ahead of a transfer.
    Compress,
    /// Codec decompression kernel behind a transfer.
    Decompress,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Compute => "compute",
            EventKind::EdgeCopy => "edge-copy",
            EventKind::Upload => "upload",
            EventKind::Download => "download",
            EventKind::Fault => "fault",
            EventKind::Prefetch => "prefetch",
            EventKind::CacheFill => "cache-fill",
            EventKind::Halo => "halo",
            EventKind::Exchange => "exchange",
            EventKind::Compress => "compress",
            EventKind::Decompress => "decompress",
        }
    }
}

/// Handle to one timeline resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResourceId(usize);

#[derive(Debug, Clone)]
struct Resource {
    name: String,
    class: StreamClass,
    /// Time at which the resource next becomes free (monotone).
    cursor: f64,
    /// Σ event durations (never exceeds the cursor: events on one
    /// resource cannot overlap).
    busy_s: f64,
    bytes: u64,
    events: u64,
}

/// One recorded event, in seconds from the timeline origin (the chain
/// start; [`crate::exec::Metrics::absorb_timeline`] rebases onto the
/// run's global clock).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Resource (stream) name the event ran on.
    pub resource: String,
    pub class: StreamClass,
    pub kind: EventKind,
    /// Human label (kernel name, `tile 7`, …); may be empty.
    pub label: String,
    pub start_s: f64,
    pub end_s: f64,
    pub bytes: u64,
}

/// A deterministic discrete-event timeline for one chain execution.
#[derive(Debug)]
pub struct Timeline {
    resources: Vec<Resource>,
    /// Event log, kept only when tracing (the busy accounting above is
    /// always on and does not need the log).
    events: Option<Vec<TraceEvent>>,
}

impl Timeline {
    /// A fresh timeline at t = 0. `tracing` controls whether individual
    /// events are logged (per-resource busy accounting always is).
    pub fn new(tracing: bool) -> Self {
        Timeline {
            resources: Vec::new(),
            events: tracing.then(Vec::new),
        }
    }

    /// A timeline whose tracing mirrors the world's metrics sink — the
    /// engines' standard entry point.
    pub fn for_world(world: &crate::exec::World<'_>) -> Self {
        Self::new(world.metrics.trace_enabled())
    }

    pub fn tracing(&self) -> bool {
        self.events.is_some()
    }

    /// Get or create the resource named `name`. A second call with the
    /// same name returns the same resource (the class of the first call
    /// sticks).
    pub fn resource(&mut self, name: &str, class: StreamClass) -> ResourceId {
        if let Some(i) = self.resources.iter().position(|r| r.name == name) {
            return ResourceId(i);
        }
        self.resources.push(Resource {
            name: name.to_string(),
            class,
            cursor: 0.0,
            busy_s: 0.0,
            bytes: 0,
            events: 0,
        });
        ResourceId(self.resources.len() - 1)
    }

    /// The time at which `r` next becomes free.
    pub fn cursor(&self, r: ResourceId) -> f64 {
        self.resources[r.0].cursor
    }

    /// Synchronise two resources: both cursors move to their max (a
    /// bidirectional stream wait, e.g. Algorithm 1's `wait streams 0&1`).
    pub fn wait(&mut self, a: ResourceId, b: ResourceId) {
        let m = self.resources[a.0].cursor.max(self.resources[b.0].cursor);
        self.resources[a.0].cursor = m;
        self.resources[b.0].cursor = m;
    }

    /// One-directional wait: `r` may not proceed before `t` (an event's
    /// end time — the dependency edge of the graph).
    pub fn wait_until(&mut self, r: ResourceId, t: f64) {
        let res = &mut self.resources[r.0];
        res.cursor = res.cursor.max(t);
    }

    /// Schedule an event on `r` starting at its cursor; returns the
    /// event's end time (= the new cursor).
    pub fn push(
        &mut self,
        r: ResourceId,
        kind: EventKind,
        label: &str,
        dur_s: f64,
        bytes: u64,
    ) -> f64 {
        let at = self.resources[r.0].cursor;
        self.push_at(r, kind, label, at, dur_s, bytes)
    }

    /// Schedule an event on `r` starting at `max(cursor, not_before)`
    /// (the dependency edge: pass another event's end time, or a
    /// point *before* the cursor to model work that began while the
    /// resource was still busy elsewhere — e.g. a prefetch overlapping
    /// the previous tile). Returns the event's end time.
    pub fn push_at(
        &mut self,
        r: ResourceId,
        kind: EventKind,
        label: &str,
        not_before: f64,
        dur_s: f64,
        bytes: u64,
    ) -> f64 {
        let res = &mut self.resources[r.0];
        let start = res.cursor.max(not_before);
        let end = start + dur_s;
        res.cursor = end;
        res.busy_s += dur_s;
        res.bytes += bytes;
        res.events += 1;
        if let Some(evs) = &mut self.events {
            evs.push(TraceEvent {
                resource: res.name.clone(),
                class: res.class,
                kind,
                label: label.to_string(),
                start_s: start,
                end_s: end,
                bytes,
            });
        }
        end
    }

    /// Schedule an event at exactly `start_s`, *without* serialising
    /// against the resource's cursor (the cursor still advances to the
    /// latest end seen). For streams that pipeline internally — the
    /// unified-memory bulk-prefetch model charges each tile's transfer
    /// against its own overlap window, with contention already folded
    /// into the degraded-efficiency calibration — so events on such a
    /// stream may overlap and its busy time may legitimately exceed its
    /// wall share ([`crate::exec::Metrics::stream_util`] saturates such
    /// a stream at 1.0: fully oversubscribed).
    pub fn push_overlapping(
        &mut self,
        r: ResourceId,
        kind: EventKind,
        label: &str,
        start_s: f64,
        dur_s: f64,
        bytes: u64,
    ) -> f64 {
        let res = &mut self.resources[r.0];
        let end = start_s + dur_s;
        res.cursor = res.cursor.max(end);
        res.busy_s += dur_s;
        res.bytes += bytes;
        res.events += 1;
        if let Some(evs) = &mut self.events {
            evs.push(TraceEvent {
                resource: res.name.clone(),
                class: res.class,
                kind,
                label: label.to_string(),
                start_s,
                end_s: end,
                bytes,
            });
        }
        end
    }

    /// The modelled wall clock: the latest cursor over all resources
    /// (0 for an empty timeline).
    pub fn makespan(&self) -> f64 {
        self.resources.iter().fold(0.0, |m, r| m.max(r.cursor))
    }

    /// Σ event durations on `r`.
    pub fn busy(&self, r: ResourceId) -> f64 {
        self.resources[r.0].busy_s
    }

    /// Iterate (name, class, busy_s, bytes, events) per resource — what
    /// [`crate::exec::Metrics::absorb_timeline`] folds in.
    pub(crate) fn resource_stats(
        &self,
    ) -> impl Iterator<Item = (&str, StreamClass, f64, u64, u64)> {
        self.resources
            .iter()
            .map(|r| (r.name.as_str(), r.class, r.busy_s, r.bytes, r.events))
    }

    /// Take the event log (empty when tracing was off).
    pub(crate) fn take_events(&mut self) -> Vec<TraceEvent> {
        self.events.take().unwrap_or_default()
    }
}

fn esc(s: &str) -> String {
    // Labels come from user-supplied loop/dataset names: escape control
    // characters too, or one newline in a kernel name invalidates the
    // whole trace file.
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render recorded events as Chrome-trace JSON (the "Trace Event
/// Format"): load the file in `chrome://tracing` or Perfetto to see the
/// modelled streams as horizontal tracks. One `tid` per resource in
/// order of first appearance, complete (`"ph":"X"`) events with
/// microsecond timestamps, byte counts and stream class in `args`.
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    let mut tids: Vec<&str> = Vec::new();
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    let mut push = |s: String, first: &mut bool, out: &mut String| {
        if !*first {
            out.push(',');
            out.push('\n');
        }
        *first = false;
        out.push_str(&s);
    };
    for ev in events {
        let tid = match tids.iter().position(|n| *n == ev.resource) {
            Some(i) => i,
            None => {
                tids.push(ev.resource.as_str());
                let i = tids.len() - 1;
                push(
                    format!(
                        "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{i},\
                         \"args\":{{\"name\":\"{}\"}}}}",
                        esc(&ev.resource)
                    ),
                    &mut first,
                    &mut out,
                );
                i
            }
        };
        let name = if ev.label.is_empty() {
            ev.kind.name()
        } else {
            ev.label.as_str()
        };
        push(
            format!(
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":0,\"tid\":{tid},\
                 \"ts\":{:.3},\"dur\":{:.3},\
                 \"args\":{{\"bytes\":{},\"stream\":\"{}\"}}}}",
                esc(name),
                ev.kind.name(),
                ev.start_s * 1e6,
                (ev.end_s - ev.start_s) * 1e6,
                ev.bytes,
                ev.class.name(),
            ),
            &mut first,
            &mut out,
        );
    }
    out.push_str("]}");
    out
}

/// [`chrome_trace_json`] plus the run's lifecycle spans as a second
/// trace process: pid 0 carries the modelled streams, pid 1 the host-
/// time span tree (one `tid` per nesting depth, so parents visually
/// contain their children). Lets `--trace` show *why* the modelled
/// clock advanced (which freeze/replay/tile phase drove it) next to the
/// streams themselves.
pub fn chrome_trace_json_with_spans(
    events: &[TraceEvent],
    spans: &[crate::obs::SpanRec],
) -> String {
    let base = chrome_trace_json(events);
    if spans.is_empty() {
        return base;
    }
    // splice span events into the traceEvents array before the closing
    // "]}" of the base render
    let mut out = String::from(&base[..base.len() - 2]);
    let had_events = !events.is_empty();
    let mut first = !had_events;
    let mut push = |s: String, first: &mut bool, out: &mut String| {
        if !*first {
            out.push(',');
            out.push('\n');
        }
        *first = false;
        out.push_str(&s);
    };
    push(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
         \"args\":{\"name\":\"lifecycle spans (host time)\"}}"
            .to_string(),
        &mut first,
        &mut out,
    );
    for sp in spans {
        push(
            format!(
                "{{\"name\":\"{}\",\"cat\":\"span\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
                 \"ts\":{:.3},\"dur\":{:.3},\"args\":{{\"depth\":{}}}}}",
                esc(&sp.name),
                sp.depth,
                sp.start_s * 1e6,
                (sp.end_s - sp.start_s) * 1e6,
                sp.depth,
            ),
            &mut first,
            &mut out,
        );
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cursors_advance_and_makespan_is_latest() {
        let mut tl = Timeline::new(false);
        let a = tl.resource("a", StreamClass::Compute);
        let b = tl.resource("b", StreamClass::Upload);
        assert_eq!(tl.makespan(), 0.0);
        let e1 = tl.push(a, EventKind::Compute, "", 2.0, 10);
        assert_eq!(e1, 2.0);
        tl.push(b, EventKind::Upload, "", 0.5, 5);
        assert_eq!(tl.makespan(), 2.0);
        // b waits on a's event, then runs 1s: ends at 3.
        tl.wait_until(b, e1);
        tl.push(b, EventKind::Upload, "", 1.0, 5);
        assert_eq!(tl.makespan(), 3.0);
        assert_eq!(tl.busy(b), 1.5);
        assert_eq!(tl.busy(a), 2.0);
    }

    #[test]
    fn wait_joins_both_cursors() {
        let mut tl = Timeline::new(false);
        let a = tl.resource("a", StreamClass::Compute);
        let b = tl.resource("b", StreamClass::Download);
        tl.push(a, EventKind::Compute, "", 4.0, 0);
        tl.wait(a, b);
        assert_eq!(tl.cursor(b), 4.0);
        assert_eq!(tl.cursor(a), 4.0);
        // busy unchanged by waits
        assert_eq!(tl.busy(b), 0.0);
    }

    #[test]
    fn push_at_models_early_start_but_never_overlaps_resource() {
        let mut tl = Timeline::new(true);
        let m = tl.resource("mig", StreamClass::Upload);
        tl.push(m, EventKind::Prefetch, "p0", 1.0, 1);
        // requested start before the cursor: clamped to the cursor
        let end = tl.push_at(m, EventKind::Prefetch, "p1", 0.2, 1.0, 1);
        assert_eq!(end, 2.0);
        // requested start after the cursor: honoured
        let end = tl.push_at(m, EventKind::Prefetch, "p2", 5.0, 1.0, 1);
        assert_eq!(end, 6.0);
        let evs = tl.take_events();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[1].start_s, 1.0);
        assert_eq!(evs[2].start_s, 5.0);
    }

    #[test]
    fn resource_lookup_is_by_name() {
        let mut tl = Timeline::new(false);
        let a = tl.resource("x", StreamClass::Compute);
        let b = tl.resource("x", StreamClass::Upload); // class of first call sticks
        assert_eq!(a, b);
        tl.push(a, EventKind::Compute, "", 1.0, 0);
        let stats: Vec<_> = tl.resource_stats().collect();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].1, StreamClass::Compute);
    }

    #[test]
    fn events_logged_only_when_tracing() {
        let mut quiet = Timeline::new(false);
        let r = quiet.resource("c", StreamClass::Compute);
        quiet.push(r, EventKind::Compute, "k", 1.0, 8);
        assert!(quiet.take_events().is_empty());
        assert_eq!(quiet.busy(r), 1.0, "busy accounting still on");

        let mut loud = Timeline::new(true);
        let r = loud.resource("c", StreamClass::Compute);
        loud.push(r, EventKind::Compute, "k", 1.0, 8);
        let evs = loud.take_events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].label, "k");
        assert_eq!(evs[0].bytes, 8);
    }

    #[test]
    fn chrome_trace_with_spans_adds_a_second_process() {
        use crate::obs::SpanRec;
        let mut tl = Timeline::new(true);
        let c = tl.resource("compute", StreamClass::Compute);
        tl.push(c, EventKind::Compute, "k", 1e-3, 64);
        let spans = vec![
            SpanRec {
                id: 0,
                parent: None,
                name: "replay".into(),
                depth: 0,
                start_s: 0.0,
                end_s: 2e-3,
                fields: Vec::new(),
            },
            SpanRec {
                id: 1,
                parent: Some(0),
                name: "chain".into(),
                depth: 1,
                start_s: 5e-4,
                end_s: 1.5e-3,
                fields: Vec::new(),
            },
        ];
        let j = chrome_trace_json_with_spans(&tl.take_events(), &spans);
        assert!(j.starts_with("{\"traceEvents\":["));
        assert!(j.ends_with("]}"));
        assert!(j.contains("\"name\":\"k\""), "engine events kept");
        assert!(j.contains("lifecycle spans (host time)"));
        assert!(j.contains("\"name\":\"replay\",\"cat\":\"span\""));
        assert!(j.contains("\"pid\":1,\"tid\":1"), "child span on depth tid");
        // no spans → byte-identical to the plain renderer
        let mut tl2 = Timeline::new(true);
        let c2 = tl2.resource("compute", StreamClass::Compute);
        tl2.push(c2, EventKind::Compute, "k", 1e-3, 64);
        let evs = tl2.take_events();
        assert_eq!(chrome_trace_json_with_spans(&evs, &[]), chrome_trace_json(&evs));
    }

    #[test]
    fn chrome_trace_renders_metadata_and_events() {
        let mut tl = Timeline::new(true);
        let c = tl.resource("compute", StreamClass::Compute);
        let u = tl.resource("upload", StreamClass::Upload);
        tl.push(u, EventKind::Upload, "tile 0", 1e-3, 4096);
        tl.push(c, EventKind::Compute, "kern\"el", 2e-3, 8192);
        let j = chrome_trace_json(&tl.take_events());
        assert!(j.starts_with("{\"traceEvents\":["));
        assert!(j.ends_with("]}"));
        assert!(j.contains("\"thread_name\""));
        assert!(j.contains("\"tile 0\""));
        assert!(j.contains("kern\\\"el"));
        assert!(j.contains("\"ph\":\"X\""));
        assert!(j.contains("\"dur\":1000.000"));
        assert!(j.contains("\"stream\":\"upload\""));
    }
}
