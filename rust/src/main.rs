//! ops-oc launcher: run any paper application on any modelled platform
//! and print the §5.1 metrics, or regenerate a figure sweep.
//!
//! Usage:
//!   ops-oc run   --app cloverleaf2d --platform knl-cache-tiled \
//!                --size-gb 48 --steps 4
//!   ops-oc run   --app cloverleaf2d --platform gpu-explicit:nvlink:cyclic x4 \
//!                --size-gb 48            (sharded across 4 modelled ranks)
//!   ops-oc run   --app opensbli --size-gb 800 \
//!                --platform "tiers:hbm=16g@509.7+host=512g@11~0.00001+nvme=4t@6~0.00002"
//!   ops-oc sweep --app opensbli --platform gpu-explicit:nvlink:cyclic:prefetch
//!   ops-oc fleet fleet:tuned-pair \
//!                --workload "tenants=8,apps=cloverleaf2d,sizes=0.01,steps=4" \
//!                --policy best-fit --json   (multi-tenant serving simulation)
//!   ops-oc list
//!   ops-oc list-platforms                 (preset topology table + grammar)
//!
//! Platform specs: knl-flat-ddr4 | knl-flat-mcdram | knl-cache |
//!   knl-cache-tiled | gpu-baseline[:link] |
//!   gpu-explicit[:link][:cyclic][:prefetch] |
//!   gpu-unified[:link][:tiled][:prefetch]     (link = pcie | nvlink)
//!   | tiers:<preset|stack>[:cyclic][:prefetch]
//!     — a declarative memory topology on the generic N-tier engine:
//!     a preset name (`tiers:knl`, `tiers:gpu-explicit-pcie`, …) or a
//!     `name=cap@bw[~lat]+…` stack, fastest tier first (run
//!     `list-platforms` for the table and grammar).
//! Sharding: append `:xN` to a shardable spec (knl-cache-tiled,
//!   gpu-explicit, gpu-unified, any tiers: stack) followed by optional
//!   `peer|nvlink|ib` (interconnect), `1d|2d` (decomposition) and
//!   `no-overlap`; or pass `--ranks N` / a bare `xN` argument. Unknown
//!   tokens are rejected.
//! `--json` emits one machine-readable metrics record per run cell,
//!   including the run's declarative `topology` spec, per-tier
//!   `util_tier_*` stream utilisation on multi-tier stacks, and the
//!   Program/Session analysis-reuse counters.
//! `--tune` / `--tune-budget E` (or a `tuned` spec token) enable the
//!   cost-model tile-plan auto-tuner on platforms with a tile plan.
//! `--fuse K` (or a `fuse:K` / `fuseK` spec token) replays K recorded
//!   fixed-dt steps as one temporally fused super-chain (0 = let the
//!   tuner pick, 1 = the unfused-replay baseline); the non-JSON output
//!   gains a greppable `fused: k=… checksum=…` witness line.
//! `--trace <path>` (run only) writes the engine's discrete-event
//!   timeline — every compute/upload/download/exchange event of the
//!   timed region, per tier when the stack is deeper than two — as
//!   Chrome-trace JSON for `chrome://tracing` or Perfetto (with the
//!   lifecycle spans as a second process row).
//! `--spans <path>` (run only) writes the hierarchical lifecycle-span
//!   tree (freeze → analyze, replay → chain → engine → tile) as JSON.
//! `--bench-out <file>` appends one flat trajectory point to a
//!   `BENCH_*.json` file; `ops-oc bench-diff <old> <new> [--tol-pct T]
//!   [--field F]` compares two such files and exits 1 on a >T%
//!   regression of the gated field (`makespan_s` by default; any
//!   numeric point field, e.g. `codec_bytes_saved` or `util_upload`).
//! `--codec <spec>` attaches a modelled compress/decompress codec to
//!   every link of a `tiers:` platform (same value grammar as the `~c:`
//!   tier annotation and the `codec:<spec>` token; conflicts between
//!   flag and token are rejected).

use ops_oc::bench_support::{self, telemetry, Figure};
use ops_oc::codec::CodecSpec;
use ops_oc::coordinator::{json_record, print_summary_with_topology, Config};
use ops_oc::exec::{chrome_trace_json_with_spans, ExecBackend};
use ops_oc::memory::AppCalib;
use ops_oc::tuner::TuneOpts;
use std::process::exit;

struct Args {
    cmd: String,
    app: String,
    platform: String,
    size_gb: f64,
    steps: usize,
    chain_steps: usize,
    ranks: u32,
    json: bool,
    tune: bool,
    tune_budget: u32,
    /// Temporal-fusion depth: `Some(k)` fuses `k` recorded steps into
    /// one super-chain (`Some(0)` = ask the tuner, `Some(1)` = the
    /// unfused replay baseline of the same chain); `None` follows the
    /// platform spec's `fuse` token, defaulting to the legacy
    /// live-driver path.
    fuse: Option<u32>,
    /// Numeric executor backend (`--exec native|vector`): vector
    /// compiles kernel IR into row programs, falling back to the
    /// closure per loop without IR; numerics are bit-identical.
    exec: ExecBackend,
    trace: Option<String>,
    spans: Option<String>,
    bench_out: Option<String>,
    tol_pct: f64,
    /// `bench-diff` gate field (`makespan_s`, `codec_bytes_saved`,
    /// `util_*`, …): which numeric per-point field regressions are
    /// judged on.
    field: String,
    /// `--codec <spec>` — attach a link codec to every link of a
    /// `tiers:` platform (value grammar of [`CodecSpec::parse`]).
    codec: Option<String>,
    /// `fleet` workload spec (`tenants=8,apps=cloverleaf2d,…`).
    workload: String,
    /// `fleet` placement policy (first-fit | best-fit | tier-aware).
    policy: String,
    /// `fleet` failure/elasticity scenarios (repeatable `--scenario`).
    scenarios: Vec<String>,
    /// Disable `fleet` fingerprint batching (freeze per request).
    no_batch: bool,
    /// Positional arguments (the two trajectory files of `bench-diff`,
    /// the cluster spec of `fleet`).
    extra: Vec<String>,
}

fn parse_args() -> Args {
    let mut a = Args {
        cmd: String::new(),
        app: "cloverleaf2d".into(),
        platform: "knl-cache-tiled".into(),
        size_gb: 24.0,
        steps: 4,
        chain_steps: 1,
        ranks: 1,
        json: false,
        tune: false,
        tune_budget: TuneOpts::default().budget,
        fuse: None,
        exec: ExecBackend::default(),
        trace: None,
        spans: None,
        bench_out: None,
        tol_pct: 10.0,
        field: "makespan_s".into(),
        codec: None,
        workload: String::new(),
        policy: "first-fit".into(),
        scenarios: vec![],
        no_batch: false,
        extra: vec![],
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "run" | "sweep" | "fleet" | "list" | "list-platforms" | "bench-diff" | "help"
            | "--help" | "-h" => a.cmd = argv[i].trim_start_matches('-').to_string(),
            "--list-platforms" => a.cmd = "list-platforms".into(),
            "--json" => a.json = true,
            "--tune" => a.tune = true,
            "--no-batch" => a.no_batch = true,
            str_flag @ ("--workload" | "--policy" | "--scenario" | "--field" | "--codec") => {
                i += 1;
                let Some(v) = argv.get(i) else {
                    eprintln!("missing value for {str_flag}");
                    exit(2);
                };
                match str_flag {
                    "--workload" => a.workload = v.clone(),
                    "--policy" => a.policy = v.clone(),
                    "--field" => a.field = v.clone(),
                    "--codec" => a.codec = Some(v.clone()),
                    _ => a.scenarios.push(v.clone()),
                }
            }
            path_flag @ ("--trace" | "--spans" | "--bench-out") => {
                i += 1;
                let Some(v) = argv.get(i) else {
                    eprintln!("missing path for {path_flag}");
                    exit(2);
                };
                match path_flag {
                    "--trace" => a.trace = Some(v.clone()),
                    "--spans" => a.spans = Some(v.clone()),
                    _ => a.bench_out = Some(v.clone()),
                }
            }
            "--exec" => {
                i += 1;
                match argv.get(i).and_then(|v| ExecBackend::parse(v)) {
                    Some(b) => a.exec = b,
                    None => {
                        eprintln!("bad value for --exec (expected native|vector)");
                        exit(2);
                    }
                }
            }
            "--tol-pct" => {
                i += 1;
                match argv.get(i).and_then(|v| v.parse::<f64>().ok()) {
                    Some(t) if t >= 0.0 => a.tol_pct = t,
                    _ => {
                        eprintln!("bad value for --tol-pct (expected a percentage >= 0)");
                        exit(2);
                    }
                }
            }
            flag @ ("--app" | "--platform" | "--size-gb" | "--steps" | "--chain-steps"
            | "--ranks" | "--tune-budget" | "--fuse") => {
                i += 1;
                let Some(v) = argv.get(i) else {
                    eprintln!("missing value for {flag}");
                    exit(2);
                };
                // numeric flags are strict: a typo must not silently run
                // with a default (same policy as the platform-spec parser)
                fn num<T: std::str::FromStr>(flag: &str, v: &str) -> T {
                    v.parse().unwrap_or_else(|_| {
                        eprintln!("bad value {v:?} for {flag}");
                        exit(2);
                    })
                }
                match flag {
                    "--app" => a.app = v.clone(),
                    "--platform" => a.platform = v.clone(),
                    "--size-gb" => a.size_gb = num(flag, v),
                    "--steps" => a.steps = num(flag, v),
                    "--ranks" => match v.parse::<u32>() {
                        Ok(n) if n >= 1 => a.ranks = n,
                        _ => {
                            eprintln!("bad rank count {v:?} (expected 1..=64)");
                            exit(2);
                        }
                    },
                    // a budget implies tuning; 0 is rejected (the
                    // heuristic always costs one evaluation)
                    "--tune-budget" => match v.parse::<u32>() {
                        Ok(n) if n >= 1 => {
                            a.tune = true;
                            a.tune_budget = n;
                        }
                        _ => {
                            eprintln!("bad tune budget {v:?} (expected >= 1)");
                            exit(2);
                        }
                    },
                    // 0 = tuner-auto, 1 = unfused replay baseline
                    "--fuse" => a.fuse = Some(num(flag, v)),
                    _ => a.chain_steps = num(flag, v),
                }
            }
            // bench-diff takes two positional trajectory files; fleet
            // takes its positional cluster spec
            other if (a.cmd == "bench-diff" || a.cmd == "fleet") && !other.starts_with('-') => {
                a.extra.push(other.to_string())
            }
            // a bare `xN` argument shards the platform (the spec-suffix
            // form `--platform gpu-explicit:…:xN` composes the same way)
            other if other.strip_prefix('x').is_some_and(|d| !d.is_empty() && d.chars().all(|c| c.is_ascii_digit())) => {
                match other[1..].parse::<u32>() {
                    Ok(n) if n >= 1 => a.ranks = n,
                    _ => {
                        eprintln!("bad rank count {other:?} (expected x1..x64)");
                        exit(2);
                    }
                }
            }
            other => {
                eprintln!("unknown argument {other:?} (try `ops-oc help`)");
                exit(2);
            }
        }
        i += 1;
    }
    a
}

/// Parse the platform spec (legacy heads and `tiers:` stacks, including
/// possible `tuned` / `fuse` tokens), apply `--ranks` and `--fuse`, and
/// build the run configuration. The app calibration is a placeholder —
/// the per-app cell runners set the right one. The second return is
/// whether fusion was *requested* (flag or spec token): `--fuse 1` runs
/// the fused pipeline at depth 1, the unfused-replay baseline the CI
/// smoke compares checksums against.
fn config_or_exit(a: &Args) -> (Config, bool) {
    let (target, spec_tuned, spec_fuse, spec_codec) =
        Config::parse_spec_opts(&a.platform).unwrap_or_else(|e| {
            eprintln!("{e}");
            exit(2);
        });
    let target = if a.ranks > 1 {
        target.sharded(a.ranks).unwrap_or_else(|e| {
            eprintln!("{e}");
            exit(2);
        })
    } else {
        target
    };
    // `--codec` mirrors the `codec` spec token (same value grammar); the
    // token's codec is already applied to the target, so the flag only
    // needs to agree with it — or apply when the spec carried none.
    let target = match &a.codec {
        None => target,
        Some(v) => {
            let c = CodecSpec::parse(v).unwrap_or_else(|e| {
                eprintln!("bad value for --codec: {e}");
                exit(2);
            });
            match spec_codec {
                Some(sc) if sc == c => target,
                Some(sc) => {
                    eprintln!(
                        "conflicting codecs: --codec {} vs spec codec:{}",
                        c.render(),
                        sc.render()
                    );
                    exit(2);
                }
                None => target.with_codec(c).unwrap_or_else(|e| {
                    eprintln!("{e}");
                    exit(2);
                }),
            }
        }
    };
    let fuse = match (a.fuse, spec_fuse) {
        (None, k) => k,
        (Some(k), 1) => k,
        (Some(k1), k2) if k1 == k2 => k1,
        (Some(k1), k2) => {
            eprintln!("conflicting fusion depths: --fuse {k1} vs spec fuse:{k2}");
            exit(2);
        }
    };
    let fused = a.fuse.is_some() || spec_fuse != 1;
    let mut cfg = Config::for_target(target, AppCalib::CLOVERLEAF_2D)
        .with_fuse(fuse)
        .with_exec(a.exec);
    // `fuse 0` in the spec is validated by the parser; the flag form is
    // validated here — the tuner needs a tile plan to score depths on.
    if fuse == 0 && cfg.tuner_target().is_none() {
        eprintln!(
            "--fuse 0 asks the auto-tuner for a fusion depth, but platform {:?} is not tunable",
            cfg.label()
        );
        exit(2);
    }
    if a.tune || spec_tuned {
        cfg = cfg
            .with_tuning(TuneOpts {
                budget: a.tune_budget,
                ..TuneOpts::default()
            })
            .unwrap_or_else(|e| {
                eprintln!("{e}");
                exit(2);
            });
    }
    (cfg, fused)
}

/// One run/sweep cell. With `fused` the app's fixed-`dt` step chain is
/// recorded once and driven by `Session::replay_fused` at depth
/// `cfg.fuse`; the extra return is `(checksum, k)` — the bit-exactness
/// witness printed for the CI fusion smoke.
#[allow(clippy::type_complexity)]
fn run_cell(
    app: &str,
    cfg: &Config,
    fused: bool,
    trace: bool,
    gb: f64,
    steps: usize,
    chain_steps: usize,
) -> (ops_oc::exec::Metrics, bool, Option<(u64, usize)>) {
    if fused {
        let r = match app {
            "cloverleaf2d" => bench_support::run_cl2d_fused_cfg(cfg, trace, 8, 6144, gb, steps),
            "cloverleaf3d" => {
                bench_support::run_cl3d_fused_cfg(cfg, trace, [8, 8, 6144], gb, steps)
            }
            "opensbli" => {
                bench_support::run_sbli_fused_cfg(cfg, trace, chain_steps, gb, steps.max(1))
            }
            other => {
                eprintln!("unknown app {other:?} (cloverleaf2d|cloverleaf3d|opensbli)");
                exit(2);
            }
        };
        return (r.metrics, r.oom, Some((r.checksum, r.k)));
    }
    let (m, oom) = match app {
        "cloverleaf2d" => bench_support::run_cl2d_cfg(cfg, trace, 8, 6144, gb, steps, 0),
        "cloverleaf3d" => bench_support::run_cl3d_cfg(cfg, trace, [8, 8, 6144], gb, steps, 0),
        "opensbli" => bench_support::run_sbli_tall_cfg(cfg, trace, chain_steps, gb, steps.max(1)),
        other => {
            eprintln!("unknown app {other:?} (cloverleaf2d|cloverleaf3d|opensbli)");
            exit(2);
        }
    };
    (m, oom, None)
}

fn list_platforms() {
    println!("preset memory topologies (run with --platform tiers:<name>):");
    println!();
    for t in ops_oc::topology::presets() {
        let name = t.name.clone().unwrap_or_default();
        println!("  {name}");
        for (i, tier) in t.tiers().iter().enumerate() {
            let cap = match tier.capacity_bytes {
                None => "unbounded".to_string(),
                Some(c) => format!("{:.1} GiB", c as f64 / (1u64 << 30) as f64),
            };
            let link = if i > 0 {
                let l = t.link(i - 1);
                let codec = match t.codec(i - 1) {
                    Some(c) => format!(", codec {}", c.render()),
                    None => String::new(),
                };
                format!("   link: {} GB/s, {} s latency{codec}", l.bw_gbs, l.latency_s)
            } else {
                String::new()
            };
            println!(
                "    tier {i}: {:<8} {:>12}  {:>7.1} GB/s{link}",
                tier.name, cap, tier.bw_gbs
            );
        }
        println!("    spec : {}", t.spec_full());
        println!();
    }
    println!("custom stacks: tiers:name=cap@bw[~lat]+name=cap@bw[~lat]+…");
    println!("  fastest tier first; cap = integer with k|m|g|t (binary) or inf");
    println!("  (last tier only); bw in GB/s; ~lat in seconds for the link");
    println!("  into the tier above (default 0.00001). Example:");
    println!("    tiers:hbm=16g@509.7+host=512g@11~0.00001+nvme=4t@6~0.00002");
    println!("  A ~c:<ratio>[@<cgbs>/<dgbs>[/<ro>]] annotation attaches a modelled");
    println!("  compress/decompress codec to the link into the tier above (the");
    println!("  first tier has none): ratio = wire compression factor, cgbs/dgbs =");
    println!("  codec kernel throughputs in GB/s (default 50/80), ro = read-only");
    println!("  ratio override for halo traffic. Example:");
    println!("    tiers:hbm=16g@509.7+host=512g@11~c:3.5");
    println!("  Options: append :cyclic, :prefetch, :tuned, :codec:<spec> (or the");
    println!("  compact :codec<spec> — attach a codec to every link; same value");
    println!("  grammar as ~c:, also the --codec flag) and/or the");
    println!("  :xN[:peer|:nvlink|:ib][:1d|:2d][:no-overlap] sharding suffix.");
    println!();
    println!("legacy platform heads map onto these preset *stacks* (Platform::topology):");
    println!("  knl-cache[-tiled] -> knl     gpu-explicit:pcie  -> gpu-explicit-pcie");
    println!("  gpu-unified:link  -> unified-<link>   gpu-explicit:nvlink -> gpu-explicit-nvlink");
    println!("  NOTE: running tiers:gpu-explicit-* is bit-exact with the legacy engine;");
    println!("  tiers:knl / tiers:unified-* describe those stacks but execute on the");
    println!("  generic explicit-streaming engine (no MCDRAM cache / page-fault model)");
    println!("  with the app's GPU compute calibration — use the legacy heads for those.");
}

fn main() {
    let a = parse_args();
    match a.cmd.as_str() {
        "" | "help" | "h" => {
            println!("ops-oc — out-of-core stencil computations (paper reproduction)");
            println!("commands:");
            println!("  run   --app A --platform P [--size-gb G] [--steps N] [--chain-steps C]");
            println!("        [--ranks R | xR] [--tune] [--tune-budget E] [--json]");
            println!("        [--fuse K]       (temporal fusion: replay K recorded steps as one");
            println!("                          super-chain; 0 = tuner-auto, 1 = unfused replay");
            println!("                          baseline; or a fuse:K / fuseK spec token)");
            println!("        [--codec C]      (attach a modelled compress/decompress codec to");
            println!("                          every link of a tiers: platform; C uses the");
            println!("                          ~c: value grammar, e.g. 3.5 or 3.5@50/80;");
            println!("                          or a codec:<C> / codec<C> spec token)");
            println!("        [--exec E]       (numeric executor: native = per-point closures,");
            println!("                          vector = compiled kernel-IR row programs with");
            println!("                          closure fallback; bit-identical numerics)");
            println!("        [--trace PATH]   (Chrome-trace JSON of the engine timeline)");
            println!("        [--spans PATH]   (hierarchical lifecycle-span tree, JSON)");
            println!("        [--bench-out F]  (append a trajectory point to F)");
            println!("  sweep --app A --platform P [--tune] [--json]  (problem-size sweep)");
            println!("  fleet SPEC --workload W [--policy P] [--scenario S]… [--no-batch]");
            println!("        [--json] [--spans PATH] [--trace PATH] [--bench-out F]");
            println!("        (multi-tenant serving simulation on a cluster of targets;");
            println!("         SPEC = fleet:<member,member*N,…> or a preset — small |");
            println!("         hetero | sharded | tuned-pair; W = tenants=8,reqs=1,");
            println!("         apps=cloverleaf2d|opensbli,sizes=0.01,steps=4,");
            println!("         arrival=closed|open@RPS,seed=S; P = first-fit | best-fit |");
            println!("         tier-aware; S = fail:<i>@t | up:<spec>@t | down:<i>@t)");
            println!("  bench-diff OLD NEW [--tol-pct T] [--field F]  (compare two BENCH_*.json");
            println!("        trajectories; exit 1 when a cell's field — makespan_s by default,");
            println!("        any numeric point field like codec_bytes_saved or util_upload");
            println!("        via --field — grew > T%, default tolerance 10%)");
            println!("  list                                          (apps + platform specs)");
            println!("  list-platforms        (preset topology table + tiers: grammar)");
        }
        "list" => {
            println!("apps      : cloverleaf2d, cloverleaf3d, opensbli");
            println!("platforms : knl-flat-ddr4, knl-flat-mcdram, knl-cache, knl-cache-tiled,");
            println!("            gpu-baseline[:link], gpu-explicit[:link][:cyclic][:prefetch],");
            println!("            gpu-unified[:link][:tiled][:prefetch]   link=pcie|nvlink");
            println!("topologies: tiers:<preset|stack>[:cyclic][:prefetch] — declarative");
            println!("            N-tier stacks on the generic engine; a three-tier");
            println!("            hbm+host+nvme stack streams problems larger than host");
            println!("            DRAM (`list-platforms` prints presets and grammar)");
            println!("sharding  : append :xN [:peer|:nvlink|:ib] [:1d|:2d] [:no-overlap]");
            println!("            to knl-cache-tiled / gpu-explicit / gpu-unified / tiers:,");
            println!("            or pass --ranks N (interconnect defaults to the host link)");
            println!("tuning    : append :tuned (or pass --tune / --tune-budget E) on any");
            println!("            platform with a tile plan; plans never model slower than");
            println!("            the HBM/3 heuristic and numerics stay bit-exact");
            println!("execution : apps run on the record-once/replay-many Program/Session");
            println!("            API — chain analysis is computed once per shape and");
            println!("            reused (--json: analysis_builds / analysis_reuse_hits);");
            println!("            --exec vector runs loop bodies as compiled kernel-IR");
            println!("            row programs (bit-exact vs native; --json reports");
            println!("            exec_backend / kir_kernels_compiled / kir_fallback_loops)");
            println!("fusion    : --fuse K (or a fuse:K spec token) replays K recorded");
            println!("            fixed-dt steps as ONE skewed super-chain — one pass");
            println!("            over the slowest tier per K steps, bit-exact against");
            println!("            K unfused replays (--json: fused_steps; K=0 asks the");
            println!("            tuner, never slower than unfused by construction)");
            println!("timelines : every engine schedules on the exec::timeline event");
            println!("            graph; --json reports bound/util_* attribution (plus");
            println!("            util_tier_* per tier) and `run --trace t.json` exports");
            println!("            the full event timeline");
        }
        "list-platforms" => list_platforms(),
        "run" => {
            let (cfg, fused) = config_or_exit(&a);
            if !a.json {
                println!(
                    "running {} on {}{} at {:.0} GB modelled ({} steps)\n",
                    a.app,
                    cfg.label(),
                    if cfg.tune.is_some() { " [tuned]" } else { "" },
                    a.size_gb,
                    a.steps
                );
            }
            let (m, oom, fuse_info) = run_cell(
                &a.app,
                &cfg,
                fused,
                a.trace.is_some(),
                a.size_gb,
                a.steps,
                a.chain_steps,
            );
            let spans = ops_oc::obs::snapshot_spans();
            if let Some(path) = &a.trace {
                let json = chrome_trace_json_with_spans(m.trace_events(), &spans);
                if let Err(e) = std::fs::write(path, &json) {
                    eprintln!("cannot write trace {path:?}: {e}");
                    exit(1);
                }
                eprintln!(
                    "wrote {} timeline events to {path} (open in chrome://tracing or Perfetto)",
                    m.trace_events().len()
                );
            }
            if let Some(path) = &a.spans {
                let json = ops_oc::obs::spans_json(&spans);
                if let Err(e) = std::fs::write(path, &json) {
                    eprintln!("cannot write spans {path:?}: {e}");
                    exit(1);
                }
                eprintln!("wrote {} lifecycle spans to {path}", spans.len());
            }
            if let Some(path) = &a.bench_out {
                let key = format!("{}|{}|{:.3}", a.app, cfg.label(), a.size_gb);
                let point =
                    telemetry::point_json(&key, &a.app, &cfg.label(), a.size_gb, &m, oom);
                if let Err(e) = telemetry::append_point(path, &point) {
                    eprintln!("cannot append trajectory point to {path:?}: {e}");
                    exit(1);
                }
                eprintln!("appended trajectory point {key:?} to {path}");
            }
            if a.json {
                println!(
                    "{}",
                    json_record(
                        &a.app,
                        &cfg.label(),
                        cfg.ranks(),
                        a.size_gb,
                        &cfg.topology(),
                        &m,
                        oom
                    )
                );
            } else {
                if let Some((checksum, k)) = fuse_info {
                    println!(
                        "fused: k={k} checksum={checksum:016x} slowest_tier_upload_bytes={}",
                        bench_support::slowest_boundary_upload_bytes(&cfg.topology(), &m)
                    );
                }
                print_summary_with_topology(
                    &format!("{} / {}", a.app, cfg.label()),
                    (a.size_gb * 1e9) as u64,
                    &cfg.topology(),
                    &m,
                    oom,
                );
            }
        }
        "fleet" => {
            let Some(spec) = a.extra.first() else {
                eprintln!(
                    "usage: ops-oc fleet <fleet:spec|preset> --workload \"tenants=8,…\" \
                     [--policy P] [--scenario S]…"
                );
                exit(2);
            };
            let cluster = ops_oc::fleet::Cluster::parse(spec).unwrap_or_else(|e| {
                eprintln!("{e}");
                exit(2);
            });
            let workload = ops_oc::fleet::Workload::parse(&a.workload).unwrap_or_else(|e| {
                eprintln!("{e}");
                exit(2);
            });
            let policy = ops_oc::fleet::Policy::parse(&a.policy).unwrap_or_else(|e| {
                eprintln!("{e}");
                exit(2);
            });
            let scenarios = a
                .scenarios
                .iter()
                .map(|s| ops_oc::fleet::Scenario::parse(s))
                .collect::<Result<Vec<_>, _>>()
                .unwrap_or_else(|e| {
                    eprintln!("{e}");
                    exit(2);
                });
            let opts = ops_oc::fleet::FleetOpts {
                policy,
                batching: !a.no_batch,
                scenarios,
                trace: a.trace.is_some(),
            };
            let run = ops_oc::fleet::serve(&cluster, &workload, &opts).unwrap_or_else(|e| {
                eprintln!("{e}");
                exit(1);
            });
            let spans = ops_oc::obs::snapshot_spans();
            if let Some(path) = &a.trace {
                let json = chrome_trace_json_with_spans(run.metrics.trace_events(), &spans);
                if let Err(e) = std::fs::write(path, &json) {
                    eprintln!("cannot write trace {path:?}: {e}");
                    exit(1);
                }
                eprintln!(
                    "wrote {} serving-timeline events to {path}",
                    run.metrics.trace_events().len()
                );
            }
            if let Some(path) = &a.spans {
                let json = ops_oc::obs::spans_json(&spans);
                if let Err(e) = std::fs::write(path, &json) {
                    eprintln!("cannot write spans {path:?}: {e}");
                    exit(1);
                }
                eprintln!("wrote {} lifecycle spans to {path}", spans.len());
            }
            if let Some(path) = &a.bench_out {
                let key = format!("fleet|{}|{}|{}", spec, a.policy, a.workload);
                let served_gb: f64 = run.outcomes.iter().map(|o| o.size_gb).sum();
                let point = telemetry::point_json(
                    &key,
                    "fleet",
                    &run.cluster_spec,
                    served_gb,
                    &run.metrics,
                    run.outcomes.iter().any(|o| o.oom),
                );
                if let Err(e) = telemetry::append_point(path, &point) {
                    eprintln!("cannot append trajectory point to {path:?}: {e}");
                    exit(1);
                }
                eprintln!("appended trajectory point {key:?} to {path}");
            }
            if a.json {
                println!("{}", ops_oc::fleet::fleet_json(&run));
            } else {
                print!("{}", ops_oc::fleet::summary(&run));
            }
        }
        "bench-diff" => {
            if a.extra.len() != 2 {
                eprintln!(
                    "usage: ops-oc bench-diff OLD.json NEW.json [--tol-pct T] [--field F]"
                );
                exit(2);
            }
            let read = |p: &str| -> String {
                std::fs::read_to_string(p).unwrap_or_else(|e| {
                    eprintln!("cannot read {p:?}: {e}");
                    exit(2);
                })
            };
            let (old_text, new_text) = (read(&a.extra[0]), read(&a.extra[1]));
            let report = telemetry::diff_field(&old_text, &new_text, a.tol_pct, &a.field)
                .unwrap_or_else(|e| {
                    eprintln!("bench-diff: {e}");
                    exit(2);
                });
            for l in &report.lines {
                println!(
                    "{} {:<48} {:>12.6} s -> {:>12.6} s  ({:+.2} %)",
                    if l.regressed { "REGRESSED" } else { "ok       " },
                    l.key,
                    l.old_s,
                    l.new_s,
                    l.delta_pct,
                );
            }
            for k in &report.missing {
                println!("missing   {k} (in {} only)", a.extra[0]);
            }
            for k in &report.added {
                println!("added     {k} (in {} only)", a.extra[1]);
            }
            let n = report.regressions();
            let gone = report.missing.len();
            // Disappeared cells are failures too: a renamed or dropped
            // bench key would otherwise hide a regression forever.
            if n > 0 || gone > 0 {
                if n > 0 {
                    eprintln!(
                        "bench-diff: {n} cell(s) regressed beyond {:.1} % tolerance",
                        a.tol_pct
                    );
                }
                if gone > 0 {
                    eprintln!(
                        "bench-diff: {gone} cell(s) disappeared from the trajectory \
                         (present in {} only)",
                        a.extra[0]
                    );
                }
                exit(1);
            }
            println!(
                "bench-diff: {} cell(s) within {:.1} % tolerance",
                report.lines.len(),
                a.tol_pct
            );
        }
        "sweep" => {
            if a.trace.is_some() {
                eprintln!("--trace applies to `run` (one cell, one trace file)");
                exit(2);
            }
            let (cfg, fused) = config_or_exit(&a);
            let mut fig = Figure::new(
                &format!(
                    "{} on {}{}",
                    a.app,
                    cfg.label(),
                    if cfg.tune.is_some() { " [tuned]" } else { "" }
                ),
                "effective GB/s (modelled)",
            );
            let s = fig.add_series(&cfg.label());
            let mut records = Vec::new();
            let (label, ranks, topo) = (cfg.label(), cfg.ranks(), cfg.topology());
            for gb in bench_support::KNL_SIZES_GB {
                let (m, oom, _) = run_cell(&a.app, &cfg, fused, false, gb, a.steps, a.chain_steps);
                if a.json {
                    records.push(json_record(&a.app, &label, ranks, gb, &topo, &m, oom));
                }
                fig.push(s, gb, (!oom).then(|| m.effective_bandwidth_gbs()));
            }
            if a.json {
                println!("[{}]", records.join(",\n "));
            } else {
                println!("{}", fig.render());
            }
        }
        other => {
            eprintln!("unknown command {other:?}");
            exit(2);
        }
    }
}
