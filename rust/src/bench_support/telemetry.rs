//! Bench-trajectory telemetry: the figure benches record one flat JSON
//! point per (app, platform, size) cell into `BENCH_<name>.json`, and
//! `ops-oc bench-diff <old> <new>` compares two trajectory files,
//! failing when any shared cell's makespan regressed by more than the
//! tolerance. Hand-rendered and hand-parsed — the crate is
//! dependency-free — but the parser tolerates pretty-printed output
//! (e.g. a file rewritten by `python3 -m json.tool`).

use crate::exec::Metrics;
use std::io;
use std::path::PathBuf;

/// FNV-1a over the parts that identify a cell's configuration, with a
/// separator byte so `("ab","c")` and `("a","bc")` digest differently.
/// Stable across runs and platforms — the digest pins a trajectory
/// point to its configuration so diffs of unrelated sweeps are caught.
pub fn config_digest(parts: &[&str]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for p in parts {
        for b in p.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h ^= 0xff;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Render one flat trajectory point. `key` identifies the cell within
/// the trajectory (diffs match on it); everything else is the cell's
/// observed telemetry.
pub fn point_json(
    key: &str,
    app: &str,
    platform: &str,
    size_gb: f64,
    m: &Metrics,
    oom: bool,
) -> String {
    let q = |p: f64| {
        m.histogram_quantiles("loop_time_s", &[p])
            .map_or(0.0, |v| v[0])
    };
    format!(
        concat!(
            "{{\"key\":\"{}\",\"app\":\"{}\",\"platform\":\"{}\",",
            "\"size_gb\":{:.3},\"makespan_s\":{:.9},\"bound\":\"{}\",",
            "\"oom\":{},\"avg_bandwidth_gbs\":{:.3},",
            "\"util_compute\":{:.4},\"util_upload\":{:.4},",
            "\"util_codec\":{:.4},\"codec_bytes_saved\":{},",
            "\"p50_loop_time_s\":{:.9},\"p99_loop_time_s\":{:.9},",
            "\"spans_recorded\":{},\"config_digest\":\"{:016x}\"}}"
        ),
        esc(key),
        esc(app),
        esc(platform),
        size_gb,
        m.elapsed_s,
        m.bound().name(),
        oom,
        m.average_bandwidth_gbs(),
        m.stream_util(crate::exec::timeline::StreamClass::Compute),
        m.stream_util(crate::exec::timeline::StreamClass::Upload),
        m.stream_util(crate::exec::timeline::StreamClass::Codec),
        m.codec_bytes_saved,
        q(0.5),
        q(0.99),
        m.spans_recorded,
        config_digest(&[app, platform, &format!("{size_gb:.3}")]),
    )
}

/// Collects trajectory points for one bench and writes
/// `BENCH_<name>.json` (a JSON array of flat points) into
/// `$OPS_OC_BENCH_DIR` or the current directory.
#[derive(Debug, Default)]
pub struct BenchRecorder {
    name: String,
    points: Vec<String>,
}

impl BenchRecorder {
    pub fn new(name: &str) -> Self {
        BenchRecorder {
            name: name.to_string(),
            points: Vec::new(),
        }
    }

    /// Record one cell's telemetry.
    pub fn point(
        &mut self,
        key: &str,
        app: &str,
        platform: &str,
        size_gb: f64,
        m: &Metrics,
        oom: bool,
    ) {
        self.points
            .push(point_json(key, app, platform, size_gb, m, oom));
    }

    /// The output path: `BENCH_<name>.json` under `$OPS_OC_BENCH_DIR`
    /// (or `.`).
    pub fn path(&self) -> PathBuf {
        let dir = std::env::var("OPS_OC_BENCH_DIR").unwrap_or_else(|_| ".".into());
        PathBuf::from(dir).join(format!("BENCH_{}.json", self.name))
    }

    /// Write the trajectory file and return its path.
    pub fn write(&self) -> io::Result<PathBuf> {
        let path = self.path();
        std::fs::write(&path, self.render())?;
        Ok(path)
    }

    /// The file contents: one point per line inside a JSON array.
    pub fn render(&self) -> String {
        let mut out = String::from("[\n");
        out.push_str(&self.points.join(",\n"));
        out.push_str("\n]\n");
        out
    }
}

/// Append one point to a trajectory file, creating it when absent —
/// the CLI's `--bench-out` accumulates runs into one file this way.
pub fn append_point(path: &str, point: &str) -> io::Result<()> {
    let existing = std::fs::read_to_string(path).unwrap_or_default();
    let trimmed = existing.trim_end();
    let out = match trimmed.strip_suffix(']') {
        Some(head) if !head.trim().is_empty() => {
            let head = head.trim_end();
            if head.ends_with('[') {
                format!("{head}\n{point}\n]\n")
            } else {
                format!("{head},\n{point}\n]\n")
            }
        }
        _ => format!("[\n{point}\n]\n"),
    };
    std::fs::write(path, out)
}

/// One parsed trajectory point: its key and makespan.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchPoint {
    pub key: String,
    pub makespan_s: f64,
}

/// Parse a trajectory file (a JSON array of flat objects). Tolerant of
/// whitespace and field order; only `key` and `makespan_s` are read.
pub fn parse_points(text: &str) -> Result<Vec<BenchPoint>, String> {
    parse_points_field(text, "makespan_s")
}

/// Like [`parse_points`], but reading an arbitrary numeric field into
/// [`BenchPoint::makespan_s`] — the `bench-diff --field` seam
/// (`codec_bytes_saved`, `util_upload`, …). A point without the field
/// is an error, not a silently passing cell.
pub fn parse_points_field(text: &str, field: &str) -> Result<Vec<BenchPoint>, String> {
    let mut points = Vec::new();
    for (i, obj) in split_objects(text)?.into_iter().enumerate() {
        let key = find_string_field(&obj, "key")
            .ok_or_else(|| format!("point {i}: missing \"key\""))?;
        let makespan_s = find_number_field(&obj, field)
            .ok_or_else(|| format!("point {i} ({key}): missing \"{field}\""))?;
        points.push(BenchPoint { key, makespan_s });
    }
    Ok(points)
}

/// Split the top-level JSON array into the text of each object,
/// tracking strings and brace depth.
fn split_objects(text: &str) -> Result<Vec<String>, String> {
    let mut objs = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut escaped = false;
    let mut start = None;
    for (i, c) in text.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' => {
                if depth == 0 {
                    start = Some(i);
                }
                depth += 1;
            }
            '}' => {
                depth = depth
                    .checked_sub(1)
                    .ok_or_else(|| "unbalanced '}'".to_string())?;
                if depth == 0 {
                    let s = start.take().ok_or_else(|| "object without start".to_string())?;
                    objs.push(text[s..=i].to_string());
                }
            }
            _ => {}
        }
    }
    if depth != 0 || in_str {
        return Err("truncated JSON".into());
    }
    Ok(objs)
}

/// Value text after `"name":` (whitespace-tolerant), up to the next
/// comma/brace at the value level.
fn field_value<'a>(obj: &'a str, name: &str) -> Option<&'a str> {
    let pat = format!("\"{name}\"");
    let mut from = 0;
    while let Some(off) = obj[from..].find(&pat) {
        let after = from + off + pat.len();
        let rest = obj[after..].trim_start();
        if let Some(v) = rest.strip_prefix(':') {
            return Some(v.trim_start());
        }
        from = after;
    }
    None
}

fn find_string_field(obj: &str, name: &str) -> Option<String> {
    let v = field_value(obj, name)?.strip_prefix('"')?;
    let mut out = String::new();
    let mut escaped = false;
    for c in v.chars() {
        if escaped {
            out.push(c);
            escaped = false;
        } else if c == '\\' {
            escaped = true;
        } else if c == '"' {
            return Some(out);
        } else {
            out.push(c);
        }
    }
    None
}

fn find_number_field(obj: &str, name: &str) -> Option<f64> {
    let v = field_value(obj, name)?;
    let end = v
        .find(|c: char| !(c.is_ascii_digit() || "+-.eE".contains(c)))
        .unwrap_or(v.len());
    v[..end].parse().ok()
}

/// One compared cell.
#[derive(Debug, Clone)]
pub struct DiffLine {
    pub key: String,
    pub old_s: f64,
    pub new_s: f64,
    pub delta_pct: f64,
    pub regressed: bool,
}

/// The result of comparing two trajectory files.
#[derive(Debug, Clone, Default)]
pub struct DiffReport {
    pub lines: Vec<DiffLine>,
    /// Keys present in the old file but not the new.
    pub missing: Vec<String>,
    /// Keys present only in the new file.
    pub added: Vec<String>,
}

impl DiffReport {
    pub fn regressions(&self) -> usize {
        self.lines.iter().filter(|l| l.regressed).count()
    }
}

/// Compare two trajectories; a cell regresses when its new makespan is
/// *strictly* above `old * (1 + tol_pct/100)` — a file diffed against
/// itself passes at any tolerance, including 0%.
pub fn diff(old_text: &str, new_text: &str, tol_pct: f64) -> Result<DiffReport, String> {
    diff_field(old_text, new_text, tol_pct, "makespan_s")
}

/// Like [`diff`], but gating on an arbitrary numeric point field
/// (`bench-diff --field`): the same strictly-above-tolerance rule,
/// applied to that field's values — an *increase* beyond tolerance is
/// the regression, so pick fields where smaller is better (times,
/// utilisations of a stream the change should relieve, bytes on the
/// wire).
pub fn diff_field(
    old_text: &str,
    new_text: &str,
    tol_pct: f64,
    field: &str,
) -> Result<DiffReport, String> {
    let old = parse_points_field(old_text, field)?;
    let new = parse_points_field(new_text, field)?;
    let mut report = DiffReport::default();
    for o in &old {
        match new.iter().find(|n| n.key == o.key) {
            None => report.missing.push(o.key.clone()),
            Some(n) => {
                let delta_pct = if o.makespan_s > 0.0 {
                    (n.makespan_s / o.makespan_s - 1.0) * 100.0
                } else {
                    0.0
                };
                let regressed = n.makespan_s > o.makespan_s * (1.0 + tol_pct / 100.0);
                report.lines.push(DiffLine {
                    key: o.key.clone(),
                    old_s: o.makespan_s,
                    new_s: n.makespan_s,
                    delta_pct,
                    regressed,
                });
            }
        }
    }
    for n in &new {
        if !old.iter().any(|o| o.key == n.key) {
            report.added.push(n.key.clone());
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m_with_time(t: f64) -> Metrics {
        let mut m = Metrics::new();
        m.record_loop("k", 1_000_000_000, t / 2.0);
        m.record_loop("k", 1_000_000_000, t / 2.0);
        m.elapsed_s = t;
        m
    }

    #[test]
    fn points_roundtrip_through_the_parser() {
        let mut rec = BenchRecorder::new("t");
        rec.point("a|6", "cl2d", "knl", 6.0, &m_with_time(0.25), false);
        rec.point("a|48", "cl2d", "knl", 48.0, &m_with_time(2.0), false);
        let pts = parse_points(&rec.render()).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].key, "a|6");
        assert!((pts[0].makespan_s - 0.25).abs() < 1e-12);
        assert!((pts[1].makespan_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn parser_tolerates_pretty_printed_json() {
        let text = "[\n  {\n    \"key\": \"cell one\",\n    \"makespan_s\": 1.5e-1,\n    \"bound\": \"idle\"\n  },\n  {\"makespan_s\":2, \"key\":\"two\"}\n]\n";
        let pts = parse_points(text).unwrap();
        assert_eq!(pts[0].key, "cell one");
        assert!((pts[0].makespan_s - 0.15).abs() < 1e-12);
        assert_eq!(pts[1].key, "two");
        assert_eq!(pts[1].makespan_s, 2.0);
    }

    #[test]
    fn self_diff_passes_at_zero_tolerance() {
        let mut rec = BenchRecorder::new("t");
        rec.point("a", "x", "p", 6.0, &m_with_time(0.5), false);
        let text = rec.render();
        let report = diff(&text, &text, 0.0).unwrap();
        assert_eq!(report.regressions(), 0);
        assert!(report.missing.is_empty() && report.added.is_empty());
    }

    #[test]
    fn regression_beyond_tolerance_is_flagged() {
        let old = "[{\"key\":\"a\",\"makespan_s\":1.0}]";
        let ok = "[{\"key\":\"a\",\"makespan_s\":1.09}]";
        let bad = "[{\"key\":\"a\",\"makespan_s\":1.2}]";
        assert_eq!(diff(old, ok, 10.0).unwrap().regressions(), 0);
        let r = diff(old, bad, 10.0).unwrap();
        assert_eq!(r.regressions(), 1);
        assert!((r.lines[0].delta_pct - 20.0).abs() < 1e-9);
    }

    #[test]
    fn diff_field_gates_on_arbitrary_numeric_fields() {
        let old = "[{\"key\":\"a\",\"makespan_s\":1.0,\"codec_bytes_saved\":100}]";
        let ok = "[{\"key\":\"a\",\"makespan_s\":9.0,\"codec_bytes_saved\":105}]";
        let bad = "[{\"key\":\"a\",\"makespan_s\":1.0,\"codec_bytes_saved\":200}]";
        // the gated field decides; makespan_s is ignored here
        let r = diff_field(old, ok, 10.0, "codec_bytes_saved").unwrap();
        assert_eq!(r.regressions(), 0);
        assert!((r.lines[0].delta_pct - 5.0).abs() < 1e-9);
        assert_eq!(diff_field(old, bad, 10.0, "codec_bytes_saved").unwrap().regressions(), 1);
        // a missing field is an error, not a silently passing cell
        assert!(diff_field(old, ok, 10.0, "bogus_field").is_err());
        // points emitted by point_json carry the codec fields
        let mut rec = BenchRecorder::new("t");
        rec.point("a", "x", "p", 6.0, &m_with_time(0.5), false);
        let text = rec.render();
        assert!(text.contains("\"util_codec\":0.0000"), "{text}");
        assert!(text.contains("\"codec_bytes_saved\":0"), "{text}");
        assert_eq!(diff_field(&text, &text, 0.0, "codec_bytes_saved").unwrap().regressions(), 0);
        assert_eq!(diff_field(&text, &text, 0.0, "util_upload").unwrap().regressions(), 0);
    }

    #[test]
    fn missing_and_added_keys_are_reported() {
        let old = "[{\"key\":\"a\",\"makespan_s\":1.0},{\"key\":\"b\",\"makespan_s\":1.0}]";
        let new = "[{\"key\":\"b\",\"makespan_s\":1.0},{\"key\":\"c\",\"makespan_s\":1.0}]";
        let r = diff(old, new, 5.0).unwrap();
        assert_eq!(r.missing, vec!["a"]);
        assert_eq!(r.added, vec!["c"]);
        assert_eq!(r.lines.len(), 1);
    }

    #[test]
    fn append_point_grows_an_array_in_place() {
        let dir = std::env::temp_dir().join("ops_oc_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_append.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        append_point(path, "{\"key\":\"a\",\"makespan_s\":1.0}").unwrap();
        append_point(path, "{\"key\":\"b\",\"makespan_s\":2.0}").unwrap();
        let pts = parse_points(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].key, "b");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn digest_separates_part_boundaries() {
        assert_ne!(config_digest(&["ab", "c"]), config_digest(&["a", "bc"]));
        assert_eq!(config_digest(&["a", "b"]), config_digest(&["a", "b"]));
    }
}
