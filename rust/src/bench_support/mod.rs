//! Shared machinery for the figure-reproduction benchmarks: problem-size
//! sweeps and series printing in the format of the paper's figures.

use crate::coordinator::{Config, Platform};
use crate::exec::Metrics;
use crate::memory::AppCalib;

pub mod telemetry;

/// Reset the span tracer for a fresh cell and, after the run, fold the
/// tracer's totals into the cell's metrics so `--json` /
/// `BENCH_*.json` report them. Every cell runner goes through this —
/// instrumentation is always-on and must not perturb the modelled
/// numbers (spans are host-time only).
fn with_span_capture<F>(run: F) -> (Metrics, bool)
where
    F: FnOnce() -> (Metrics, bool),
{
    crate::obs::reset();
    let (mut m, oom) = run();
    let st = crate::obs::span_stats();
    m.spans_recorded = st.total;
    m.span_max_depth = st.max_depth;
    (m, oom)
}

/// A point of one figure series.
#[derive(Debug, Clone)]
pub struct Point {
    pub problem_gb: f64,
    pub value: Option<f64>,
}

/// One line of a figure.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<Point>,
}

/// A figure: a set of series over problem sizes.
#[derive(Debug, Clone, Default)]
pub struct Figure {
    pub title: String,
    pub ylabel: String,
    pub series: Vec<Series>,
}

impl Figure {
    pub fn new(title: &str, ylabel: &str) -> Self {
        Figure {
            title: title.to_string(),
            ylabel: ylabel.to_string(),
            series: vec![],
        }
    }

    pub fn add_series(&mut self, label: &str) -> usize {
        self.series.push(Series {
            label: label.to_string(),
            points: vec![],
        });
        self.series.len() - 1
    }

    pub fn push(&mut self, series: usize, problem_gb: f64, value: Option<f64>) {
        self.series[series].points.push(Point { problem_gb, value });
    }

    /// Render the figure as an aligned text table (rows = problem sizes,
    /// columns = series) — the same rows/series the paper plots.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("### {}\n", self.title));
        out.push_str(&format!("(values: {})\n", self.ylabel));
        let mut sizes: Vec<f64> = self
            .series
            .iter()
            .flat_map(|s| s.points.iter().map(|p| p.problem_gb))
            .collect();
        sizes.sort_by(|a, b| a.total_cmp(b));
        sizes.dedup_by(|a, b| (*a - *b).abs() < 1e-9);

        out.push_str(&format!("{:>10}", "size(GB)"));
        for s in &self.series {
            out.push_str(&format!("  {:>24}", s.label));
        }
        out.push('\n');
        for sz in sizes {
            out.push_str(&format!("{sz:>10.1}"));
            for s in &self.series {
                let v = s
                    .points
                    .iter()
                    .find(|p| (p.problem_gb - sz).abs() < 1e-9)
                    .and_then(|p| p.value);
                match v {
                    Some(v) => out.push_str(&format!("  {v:>24.1}")),
                    None => out.push_str(&format!("  {:>24}", "-")),
                }
            }
            out.push('\n');
        }
        out
    }
}

/// Run one (platform, app, size) cell through the legacy eager context
/// and return the effective bandwidth (None = OOM, matching the paper's
/// truncated series).
#[deprecated(
    since = "0.3.0",
    note = "drives the deprecated OpsContext shim; the cell runners below use the \
            Program/Session API"
)]
#[allow(deprecated)]
pub fn run_cell<F>(platform: Platform, app_calib: AppCalib, steps: usize, app: F) -> Option<f64>
where
    F: FnOnce(&mut crate::ops::OpsContext, usize),
{
    let cfg = Config::new(platform, app_calib);
    let (m, oom) = crate::coordinator::run_app(&cfg, steps, app);
    if oom {
        None
    } else {
        Some(m.effective_bandwidth_gbs())
    }
}

/// Like [`run_cell`] but returns the full metrics (hit rates etc.).
#[deprecated(
    since = "0.3.0",
    note = "drives the deprecated OpsContext shim; the cell runners below use the \
            Program/Session API"
)]
#[allow(deprecated)]
pub fn run_cell_metrics<F>(
    platform: Platform,
    app_calib: AppCalib,
    steps: usize,
    app: F,
) -> (Metrics, bool)
where
    F: FnOnce(&mut crate::ops::OpsContext, usize),
{
    let cfg = Config::new(platform, app_calib);
    crate::coordinator::run_app(&cfg, steps, app)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_renders_missing_points_as_dash() {
        let mut f = Figure::new("t", "GB/s");
        let a = f.add_series("a");
        let b = f.add_series("b");
        f.push(a, 6.0, Some(100.0));
        f.push(a, 16.0, Some(90.0));
        f.push(b, 6.0, Some(50.0));
        f.push(b, 16.0, None);
        let r = f.render();
        assert!(r.contains("100.0"));
        assert!(r.contains('-'));
        assert!(r.lines().count() >= 4);
    }
}

// ---------------------------------------------------------------------------
// App cell-runners shared by the figure benches, the smoke tests and the
// CLI launcher. Each runs one (app, platform, modelled-size) cell
// through the Program/Session API: real numerics on a small grid, byte
// accounting scaled to the paper's sizes, chain analysis amortised
// across the run (visible as `analysis_builds`/`analysis_reuse_hits`).

use crate::apps::cloverleaf2d::CloverLeaf2D;
use crate::apps::cloverleaf3d::CloverLeaf3D;
use crate::apps::opensbli::OpenSbli;
use crate::program::{ProgramBuilder, Session};
use std::sync::Arc;

/// Modelled bytes of an app at `model_scale = 1`.
pub fn base_bytes<F>(declare: F) -> u64
where
    F: FnOnce(&mut ProgramBuilder),
{
    let mut b = ProgramBuilder::new();
    declare(&mut b);
    b.problem_bytes()
}

/// Freeze a declared builder and bind it to the configured engine.
fn freeze_session(b: ProgramBuilder, cfg: &Config) -> Session {
    let program = Arc::new(b.freeze().expect("app program must freeze"));
    Session::new(program, cfg)
}

/// Scale factor that makes an app with `base` bytes model `target_gb`.
pub fn model_scale(base: u64, target_gb: f64) -> u64 {
    ((target_gb * 1e9 / base as f64).round() as u64).max(1)
}

/// Optionally wrap a cell's config in the auto-tuner. `None` keeps the
/// heuristic planner. **Panics** when `tune` is `Some` on a platform
/// with no tile plan — the `run_*_tuned` cell runners inherit this
/// contract, so callers must pre-validate (the CLI does, via
/// `Config::parse_spec` / `with_tuning`).
fn apply_tuning(cfg: Config, tune: Option<crate::tuner::TuneOpts>) -> Config {
    match tune {
        Some(t) => cfg.with_tuning(t).expect("platform must be tunable"),
        None => cfg,
    }
}

/// One CloverLeaf 2D cell. Returns (metrics, oom).
pub fn run_cl2d(
    platform: Platform,
    nx: usize,
    ny: usize,
    target_gb: f64,
    steps: usize,
    summary_every: usize,
) -> (Metrics, bool) {
    run_cl2d_tuned(platform, None, nx, ny, target_gb, steps, summary_every)
}

/// [`run_cl2d`] with an optional auto-tuner.
pub fn run_cl2d_tuned(
    platform: Platform,
    tune: Option<crate::tuner::TuneOpts>,
    nx: usize,
    ny: usize,
    target_gb: f64,
    steps: usize,
    summary_every: usize,
) -> (Metrics, bool) {
    run_cl2d_cell(platform, tune, false, nx, ny, target_gb, steps, summary_every)
}

/// Full-option CloverLeaf 2D cell: auto-tuner and timeline tracing
/// (`trace: true` collects every engine event for the `--trace`
/// Chrome-trace export; the returned metrics carry them in
/// `trace_events()`).
#[allow(clippy::too_many_arguments)]
pub fn run_cl2d_cell(
    platform: Platform,
    tune: Option<crate::tuner::TuneOpts>,
    trace: bool,
    nx: usize,
    ny: usize,
    target_gb: f64,
    steps: usize,
    summary_every: usize,
) -> (Metrics, bool) {
    let cfg = apply_tuning(Config::new(platform, AppCalib::CLOVERLEAF_2D), tune);
    run_cl2d_cfg(&cfg, trace, nx, ny, target_gb, steps, summary_every)
}

/// CloverLeaf 2D cell driven by a full [`Config`] — the new-API entry
/// point the CLI uses: the config's target may be a legacy platform or
/// any declarative `tiers:` stack (sharded or not). The app calibration
/// is set to CloverLeaf 2D's regardless of what the config carried.
#[allow(clippy::too_many_arguments)]
pub fn run_cl2d_cfg(
    cfg: &Config,
    trace: bool,
    nx: usize,
    ny: usize,
    target_gb: f64,
    steps: usize,
    summary_every: usize,
) -> (Metrics, bool) {
    let mut cfg = cfg.clone();
    cfg.app = AppCalib::CLOVERLEAF_2D;
    with_span_capture(|| {
        let base = base_bytes(|b| {
            CloverLeaf2D::new(b, nx, ny, 1);
        });
        let scale = model_scale(base, target_gb);
        let mut b = ProgramBuilder::new();
        let mut app = CloverLeaf2D::new(&mut b, nx, ny, scale);
        let mut sess = freeze_session(b, &cfg);
        if trace {
            sess.metrics_mut().enable_trace();
        }
        app.run(&mut sess, steps, summary_every);
        (sess.metrics().clone(), sess.oom())
    })
}

/// One CloverLeaf 3D cell.
pub fn run_cl3d(
    platform: Platform,
    n: [usize; 3],
    target_gb: f64,
    steps: usize,
    summary_every: usize,
) -> (Metrics, bool) {
    run_cl3d_tuned(platform, None, n, target_gb, steps, summary_every)
}

/// [`run_cl3d`] with an optional auto-tuner.
pub fn run_cl3d_tuned(
    platform: Platform,
    tune: Option<crate::tuner::TuneOpts>,
    n: [usize; 3],
    target_gb: f64,
    steps: usize,
    summary_every: usize,
) -> (Metrics, bool) {
    run_cl3d_cell(platform, tune, false, n, target_gb, steps, summary_every)
}

/// Full-option CloverLeaf 3D cell (see [`run_cl2d_cell`]).
#[allow(clippy::too_many_arguments)]
pub fn run_cl3d_cell(
    platform: Platform,
    tune: Option<crate::tuner::TuneOpts>,
    trace: bool,
    n: [usize; 3],
    target_gb: f64,
    steps: usize,
    summary_every: usize,
) -> (Metrics, bool) {
    let cfg = apply_tuning(Config::new(platform, AppCalib::CLOVERLEAF_3D), tune);
    run_cl3d_cfg(&cfg, trace, n, target_gb, steps, summary_every)
}

/// CloverLeaf 3D cell driven by a full [`Config`] (see
/// [`run_cl2d_cfg`]).
pub fn run_cl3d_cfg(
    cfg: &Config,
    trace: bool,
    n: [usize; 3],
    target_gb: f64,
    steps: usize,
    summary_every: usize,
) -> (Metrics, bool) {
    let mut cfg = cfg.clone();
    cfg.app = AppCalib::CLOVERLEAF_3D;
    with_span_capture(|| {
        let base = base_bytes(|b| {
            CloverLeaf3D::new(b, n[0], n[1], n[2], 1);
        });
        let scale = model_scale(base, target_gb);
        let mut b = ProgramBuilder::new();
        let mut app = CloverLeaf3D::new(&mut b, n[0], n[1], n[2], scale);
        let mut sess = freeze_session(b, &cfg);
        if trace {
            sess.metrics_mut().enable_trace();
        }
        app.run(&mut sess, steps, summary_every);
        (sess.metrics().clone(), sess.oom())
    })
}

/// One OpenSBLI cell; `steps_per_chain` is the §5.3 tile-depth knob.
pub fn run_sbli(
    platform: Platform,
    n: usize,
    steps_per_chain: usize,
    target_gb: f64,
    chains: usize,
) -> (Metrics, bool) {
    with_span_capture(|| {
        let base = base_bytes(|b| {
            OpenSbli::new(b, n, steps_per_chain, 1);
        });
        let scale = model_scale(base, target_gb);
        let cfg = Config::new(platform, AppCalib::OPENSBLI);
        let mut b = ProgramBuilder::new();
        let mut app = OpenSbli::new(&mut b, n, steps_per_chain, scale);
        let mut sess = freeze_session(b, &cfg);
        app.run(&mut sess, chains);
        (sess.metrics().clone(), sess.oom())
    })
}

/// Effective-bandwidth value for a figure point (None on OOM — the paper
/// plots truncated series where flat-MCDRAM/GPU-baseline segfault).
pub fn bw_point(res: (Metrics, bool)) -> Option<f64> {
    if res.1 {
        None
    } else {
        Some(res.0.effective_bandwidth_gbs())
    }
}

/// The problem sizes (GB) the paper's KNL scaling figures sweep.
pub const KNL_SIZES_GB: [f64; 8] = [6.0, 12.0, 16.0, 20.0, 24.0, 32.0, 40.0, 48.0];
/// The GPU scaling sweep.
pub const GPU_SIZES_GB: [f64; 7] = [6.0, 10.0, 13.0, 16.0, 24.0, 36.0, 47.0];

/// OpenSBLI cell on the tall-z bench grid (24×24×384): z has room for
/// deep skewed tiles; x/y stay small for runtime.
pub fn run_sbli_tall(
    platform: Platform,
    steps_per_chain: usize,
    target_gb: f64,
    chains: usize,
) -> (Metrics, bool) {
    run_sbli_tall_tuned(platform, None, steps_per_chain, target_gb, chains)
}

/// [`run_sbli_tall`] with an optional auto-tuner.
pub fn run_sbli_tall_tuned(
    platform: Platform,
    tune: Option<crate::tuner::TuneOpts>,
    steps_per_chain: usize,
    target_gb: f64,
    chains: usize,
) -> (Metrics, bool) {
    run_sbli_tall_cell(platform, tune, false, steps_per_chain, target_gb, chains)
}

/// Full-option tall-z OpenSBLI cell (see [`run_cl2d_cell`]).
pub fn run_sbli_tall_cell(
    platform: Platform,
    tune: Option<crate::tuner::TuneOpts>,
    trace: bool,
    steps_per_chain: usize,
    target_gb: f64,
    chains: usize,
) -> (Metrics, bool) {
    let cfg = apply_tuning(Config::new(platform, AppCalib::OPENSBLI), tune);
    run_sbli_tall_cfg(&cfg, trace, steps_per_chain, target_gb, chains)
}

// ---------------------------------------------------------------------------
// Temporal-fusion cell runners: record the app's fixed-`dt` step chain
// once, then drive it with [`Session::replay_fused`] so `k` recorded
// steps run as one skewed super-chain. `cfg.fuse` selects the depth
// (1 = unfused replay, 0 = ask the tuner). Numerics are bit-exact
// against unfused replay of the same chain — the returned checksum is
// the witness the CI smoke and `benches/fig_temporal_fusion.rs` compare
// across depths.

/// Upper fusion depth the tuner grid explores when `cfg.fuse == 0`.
pub const DEFAULT_MAX_FUSE: u32 = 8;

/// Result of one fused cell: metrics, OOM flag, the bit-exactness
/// checksum over every dataset buffer, and the fusion depth actually
/// used (tuner-resolved when the config asked for `fuse = 0`).
#[derive(Debug, Clone)]
pub struct FusedRun {
    pub metrics: Metrics,
    pub oom: bool,
    pub checksum: u64,
    pub k: usize,
}

/// Order-sensitive FNV-1a over the raw bit patterns of every dataset
/// buffer — equal checksums mean bit-identical fields.
pub fn store_checksum(sess: &Session) -> u64 {
    let mut h = crate::tiling::analysis::Fnv::new();
    h.write_u64(sess.store().len() as u64);
    for id in 0..sess.store().len() {
        let buf = sess.store().buf(crate::ops::DatasetId(id as u32));
        h.write_u64(buf.len() as u64);
        for v in buf {
            h.write_u64(v.to_bits());
        }
    }
    h.finish()
}

/// Bytes moved over the topology's *slowest* boundary (the paper's
/// out-of-core cost): the upload stream feeding the second-to-last tier
/// for ≥3-tier stacks (`"{tier}:upload"`), the bare `"upload"` stream
/// for 2-tier stacks and the legacy GPU engines. Sharded runs prefix
/// streams with `r{rank}:`, so matching is by suffix; all matching
/// ranks are summed.
pub fn slowest_boundary_upload_bytes(topo: &crate::topology::Topology, m: &Metrics) -> u64 {
    let tiers = topo.tiers();
    let name = if tiers.len() >= 3 {
        format!("{}:upload", tiers[tiers.len() - 2].name)
    } else {
        "upload".to_string()
    };
    let suffix = format!(":{name}");
    m.per_resource
        .iter()
        .filter(|(key, _)| **key == name || key.ends_with(&suffix))
        .map(|(_, st)| st.bytes)
        .sum()
}

/// Resolve a config's fusion depth against a frozen step chain:
/// `fuse = k` is taken literally, `fuse = 0` asks
/// [`crate::tuner::tune_fuse`] (geometric grid up to
/// [`DEFAULT_MAX_FUSE`], never worse than `k = 1` by construction).
fn resolve_fuse(cfg: &Config, sess: &Session, step: crate::program::ChainId) -> usize {
    match cfg.fuse {
        0 => match cfg.tuner_target() {
            Some(target) => {
                let spec = sess.program().chain(step);
                let opts = cfg.tune.unwrap_or_default();
                crate::tuner::tune_fuse(
                    &target,
                    &opts,
                    &spec.loops,
                    sess.datasets(),
                    sess.stencils(),
                    true,
                    DEFAULT_MAX_FUSE,
                )
                .candidate
                .fuse as usize
            }
            None => 1,
        },
        k => k as usize,
    }
}

/// Shared tail of the fused runners: initialise live, freeze metrics,
/// resolve the depth, replay the step chain fused, checksum.
fn drive_fused<A>(
    cfg: &Config,
    trace: bool,
    mut app: A,
    b: ProgramBuilder,
    step: crate::program::ChainId,
    replays: usize,
    init: impl FnOnce(&mut A, &mut Session),
) -> FusedRun {
    use crate::ops::Drive;
    let mut checksum = 0u64;
    let mut k_used = 1usize;
    let (metrics, oom) = with_span_capture(|| {
        let mut sess = freeze_session(b, cfg);
        if trace {
            sess.metrics_mut().enable_trace();
        }
        init(&mut app, &mut sess);
        sess.flush();
        sess.reset_metrics();
        sess.set_cyclic_phase(true);
        let k = resolve_fuse(cfg, &sess, step);
        sess.replay_fused(step, replays, k);
        sess.flush();
        checksum = store_checksum(&sess);
        k_used = k;
        (sess.metrics().clone(), sess.oom())
    });
    FusedRun {
        metrics,
        oom,
        checksum,
        k: k_used,
    }
}

/// Fused CloverLeaf 2D cell: `replays` fixed-`dt` double steps (the
/// recorded chain covers both advection parities), fused `cfg.fuse` at
/// a time.
pub fn run_cl2d_fused_cfg(
    cfg: &Config,
    trace: bool,
    nx: usize,
    ny: usize,
    target_gb: f64,
    replays: usize,
) -> FusedRun {
    let mut cfg = cfg.clone();
    cfg.app = AppCalib::CLOVERLEAF_2D;
    let base = base_bytes(|b| {
        CloverLeaf2D::new(b, nx, ny, 1);
    });
    let scale = model_scale(base, target_gb);
    let mut b = ProgramBuilder::new();
    let mut app = CloverLeaf2D::new(&mut b, nx, ny, scale);
    let step = app.record_step_chain(&mut b);
    drive_fused(&cfg, trace, app, b, step, replays, |app, sess| {
        app.initialise(sess)
    })
}

/// Fused CloverLeaf 3D cell (see [`run_cl2d_fused_cfg`]).
pub fn run_cl3d_fused_cfg(
    cfg: &Config,
    trace: bool,
    n: [usize; 3],
    target_gb: f64,
    replays: usize,
) -> FusedRun {
    let mut cfg = cfg.clone();
    cfg.app = AppCalib::CLOVERLEAF_3D;
    let base = base_bytes(|b| {
        CloverLeaf3D::new(b, n[0], n[1], n[2], 1);
    });
    let scale = model_scale(base, target_gb);
    let mut b = ProgramBuilder::new();
    let mut app = CloverLeaf3D::new(&mut b, n[0], n[1], n[2], scale);
    let step = app.record_step_chain(&mut b);
    drive_fused(&cfg, trace, app, b, step, replays, |app, sess| {
        app.initialise(sess)
    })
}

/// Fused tall-z OpenSBLI cell: `chains` chains of `steps_per_chain`
/// timesteps, fused `cfg.fuse` chains at a time (pure replay — no
/// halo exchange between chains, matching what the unfused
/// [`Session::replay`] baseline of the same chain does).
pub fn run_sbli_fused_cfg(
    cfg: &Config,
    trace: bool,
    steps_per_chain: usize,
    target_gb: f64,
    chains: usize,
) -> FusedRun {
    let n = [24usize, 24, 1024];
    let mut cfg = cfg.clone();
    cfg.app = AppCalib::OPENSBLI;
    let base = base_bytes(|b| {
        OpenSbli::new_aniso(b, n, steps_per_chain, 1);
    });
    let scale = model_scale(base, target_gb);
    let mut b = ProgramBuilder::new();
    let mut app = OpenSbli::new_aniso(&mut b, n, steps_per_chain, scale);
    let step = app.record_step_chain(&mut b);
    drive_fused(&cfg, trace, app, b, step, chains, |app, sess| {
        app.initialise(sess)
    })
}

/// Tall-z OpenSBLI cell driven by a full [`Config`] (see
/// [`run_cl2d_cfg`]).
pub fn run_sbli_tall_cfg(
    cfg: &Config,
    trace: bool,
    steps_per_chain: usize,
    target_gb: f64,
    chains: usize,
) -> (Metrics, bool) {
    let n = [24usize, 24, 1024];
    let mut cfg = cfg.clone();
    cfg.app = AppCalib::OPENSBLI;
    with_span_capture(|| {
        let base = base_bytes(|b| {
            OpenSbli::new_aniso(b, n, steps_per_chain, 1);
        });
        let scale = model_scale(base, target_gb);
        let mut b = ProgramBuilder::new();
        let mut app = OpenSbli::new_aniso(&mut b, n, steps_per_chain, scale);
        let mut sess = freeze_session(b, &cfg);
        if trace {
            sess.metrics_mut().enable_trace();
        }
        app.run(&mut sess, chains);
        (sess.metrics().clone(), sess.oom())
    })
}
