//! # ops-oc — Out-of-Core Stencil Computations
//!
//! A reproduction of *"Beyond 16GB: Out-of-Core Stencil Computations"*
//! (Reguly, Mudalige, Giles — 2017) as a production-style Rust + JAX +
//! Pallas stack.
//!
//! The crate implements an OPS-style structured-mesh DSL: users declare
//! [`ops::Block`]s, [`ops::Dataset`]s, [`ops::Stencil`]s through a
//! [`ProgramBuilder`], record *parallel loops* into named frozen chains
//! ([`ProgramBuilder::record_chain`]) or dynamically into a lazy queue,
//! freeze an immutable [`Program`] (whose per-chain dependency/footprint
//! analysis is computed exactly once), and execute through [`Session`]s
//! — `session.replay(chain, n)` replays a recorded step `n` times, and
//! many sessions can share one program. When a trigger point returns
//! data to the user (a reduction result, a dataset fetch), the pending
//! *chain* is analysed (or its cached analysis reused), a skewed tiling
//! schedule is computed ([`tiling::TilePlan`]) and the chain is executed
//! through one of the memory engines:
//!
//! * [`memory::KnlEngine`] — KNL MCDRAM in flat/cache mode (direct-mapped
//!   cache simulator),
//! * [`memory::GpuExplicitEngine`] — the paper's Algorithm 1: triple
//!   buffered ("three slots") explicit streaming over PCIe/NVLink,
//! * [`memory::UnifiedEngine`] — CUDA Unified-Memory-style page migration.
//!
//! **Numerics are real** (tiled execution is verified identical to untiled
//! execution), **time is simulated**: the engines drive a discrete-event
//! clock calibrated against the paper's measured STREAM and baseline
//! numbers, and the headline metric — weighted *Average Bandwidth*
//! (§5.1 of the paper) — is computed from actual bytes touched per loop
//! divided by modelled runtime.
//!
//! The compute hot-spots are also available as AOT-compiled XLA programs
//! (JAX/Pallas → HLO text → PJRT; see `python/compile` and
//! [`runtime`]), exercised by the [`exec::PjrtExecutor`] backend (gated
//! behind the `xla` cargo feature — the default build is dependency-free
//! and the executor falls back to a stub that reports PJRT unavailable).
//!
//! ## Multi-device execution
//!
//! The [`distributed`] subsystem shards a declared block across N
//! modelled ranks under a 1D or 2D [`distributed::Decomposition`], each
//! rank owning its own memory engine (KNL cache-tiled, GPU-explicit or
//! unified). Inter-rank halos are planned by
//! [`distributed::HaloExchange`] from the same per-chain access analysis
//! the tiler uses, costed over a calibrated
//! [`distributed::Interconnect`] (PCIe peer / NVLink / InfiniBand), and
//! overlapped with interior compute by
//! [`distributed::ShardedEngine`]. Select it from the CLI with the `xN`
//! platform-spec suffix (e.g. `gpu-explicit:nvlink:cyclic:x4:ib`) or the
//! `--ranks` flag — see `rust/README.md` for the full grammar.
//!
//! ## Auto-tuning
//!
//! The [`tuner`] subsystem replaces the engines' fixed `HBM/3`-style
//! tile heuristic with a deterministic, seeded search over tile counts
//! and the §4.1 toggles, scored on the engines' own discrete-event
//! clocks and memoised in a process-wide plan cache. Tuned plans are
//! guaranteed to never *model* slower than the heuristic and leave
//! numerics bit-exact. Enable with `--tune`, a `tuned` spec token, or
//! [`coordinator::Config::with_tuning`].
//!
//! ## Timelines, tracing & bottleneck attribution
//!
//! Every engine schedules on one shared substrate: the
//! [`exec::timeline`] discrete-event simulator. Named resources model
//! the platform's concurrent streams (compute/upload/download for
//! Algorithm 1, MCDRAM/DDR4 for cache mode, per-rank interconnect
//! links when sharded); waits and overlaps are edges in one event
//! graph, and the chain's modelled wall clock is its makespan. The
//! recorded events feed per-stream busy/idle **bottleneck attribution**
//! (`bound` + `util_*` in the `--json` record and the run summary) and
//! the `--trace <path>` Chrome-trace export (`chrome://tracing` /
//! Perfetto).
//!
//! ## Declarative memory topologies
//!
//! The paper's two-level pairings generalise: a [`topology::Topology`]
//! describes any ordered stack of memory tiers (name, capacity,
//! bandwidth) with [`topology::LinkSpec`] edges, parsed from a compact
//! grammar (`--platform tiers:hbm=16g@509.7+host=48g@11~0.00001+nvme=inf@6`)
//! or picked from named presets that reproduce the paper's calibrations
//! (`tiers:knl`, `tiers:gpu-explicit-pcie`, … — `ops-oc list-platforms`
//! prints the table). The generic [`memory::TieredEngine`] lowers any
//! N-tier stack onto the timeline by applying Algorithm 1 recursively
//! at every capacity boundary — a three-tier HBM→host→NVMe run models
//! problems larger than host DRAM with per-tier stream attribution.
//! Two-tier GPU stacks reproduce [`memory::GpuExplicitEngine`]'s
//! modelled clocks bit-exactly; the legacy [`Platform`] enum survives
//! as a thin compatibility layer over the presets
//! ([`Platform::topology`]).
//!
//! ## Compression-aware tier links
//!
//! The [`codec`] subsystem models compression on the traffic crossing a
//! tier boundary or the inter-rank interconnect: a [`codec::CodecSpec`]
//! (ratio + compress/decompress throughput, optional read-only ratio
//! override) attaches to any link via the `~c:` tier annotation
//! (`tiers:hbm=16g@509.7+host=512g@11~c:3.5`), a `codec` spec token, or
//! the `--codec` flag. Engines emit compress → transfer(wire bytes) →
//! decompress as first-class timeline streams (`codec`,
//! `<tier>:codec`, `r<rank>:codec`), so the attribution surfaces show
//! when a link flips from transfer-bound to **codec-bound**, and the
//! byte ledger reports `codec_bytes_saved`. Numerics are untouched by
//! construction; a ratio-1.0 codec is bit-identical to no codec. The
//! tuner searches a per-target codec on/off toggle with the same
//! never-worse guarantee as every other dimension.
//!
//! ## Observability
//!
//! The [`obs`] subsystem is the telemetry layer the §5.1 evaluation
//! rests on: Average Bandwidth is bytes touched per loop over modelled
//! runtime, and [`obs`] attributes both sides of that fraction.
//! Hierarchical lifecycle spans ([`obs::span`], exported by `--spans`
//! or merged into the Chrome trace) cover freeze → chain analysis →
//! tuner candidates → replay → per-tile execution → halo exchange; a
//! mergeable metrics registry ([`obs::Registry`] on
//! [`exec::Metrics::obs`]) keeps log-linear histograms of per-loop and
//! per-exchange timings with p50/p90/p99 bounds
//! ([`exec::Metrics::histogram_quantiles`]); and the roofline report
//! ([`obs::roofline`]) compares each stream's modelled achieved GB/s
//! against its tier/link peak from the [`topology::Topology`].
//! `bench_support::telemetry` serialises the same numbers into
//! `BENCH_<name>.json` trajectory records gated by `ops-oc bench-diff`.
//!
//! ## Fleet serving
//!
//! The [`fleet`] subsystem turns the single-run engine into a
//! multi-tenant service: a declarative [`fleet::Cluster`] of
//! heterogeneous targets (`fleet:` spec grammar with presets and
//! `*<count>` multiplicities), a deterministic seeded
//! [`fleet::Workload`] of tenant requests (open- and closed-loop
//! arrivals), and a discrete-event scheduler ([`fleet::serve`]) with
//! first-fit / best-fit / tier-aware placement. Identical-fingerprint
//! requests share one frozen [`Program`] — freeze-time chain analysis
//! and process-wide tuned plans are built once and hit from every
//! other tenant — while rank-failure and scale-up/down
//! [`fleet::Scenario`]s exercise re-decomposition mid-trace. Reports
//! flow through the same surfaces as single runs: `fleet_*` keys in
//! `--json`, a `fleet` span tree in `--spans`, per-request engine
//! timelines on the serving clock in `--trace`, and
//! `BENCH_fleet.json` trajectory points. CLI:
//! `ops-oc fleet <spec> --workload …`.

pub mod apps;
pub mod bench_support;
pub mod codec;
pub mod coordinator;
pub mod distributed;
pub mod errors;
pub mod exec;
pub mod fleet;
pub mod lazy;
pub mod memory;
pub mod obs;
pub mod ops;
pub mod program;
pub mod runtime;
pub mod tiling;
pub mod topology;
pub mod tuner;

pub use coordinator::config::{Config, Platform, Target, TieredTarget};
#[allow(deprecated)]
pub use ops::api::OpsContext;
pub use program::{Program, ProgramBuilder, Session};

/// Crate-wide result type.
pub type Result<T> = errors::Result<T>;
