//! END-TO-END VALIDATION DRIVER (recorded in EXPERIMENTS.md).
//!
//! Exercises every layer of the stack on one real workload:
//!
//!  * L1/L2 — the `cl2d_ideal_gas` kernel executes through the
//!    AOT-compiled JAX/Pallas artifact via PJRT (when `make artifacts`
//!    has run; it falls back to native with a warning otherwise);
//!  * L3 — CloverLeaf 2D (63-loop chains, 25 datasets) runs through the
//!    explicit 3-slot GPU streaming coordinator at 3x memory
//!    oversubscription, plus the KNL cache-mode path;
//!  * physics — the field_summary conservation table is printed and
//!    checked, and the tiled run is compared bit-for-bit against the
//!    untiled reference.
//!
//!     make artifacts && cargo run --release --example e2e_cloverleaf

use ops_oc::apps::cloverleaf2d::CloverLeaf2D;
use ops_oc::coordinator::{print_summary, Config, Platform};
use ops_oc::exec::PjrtExecutor;
use ops_oc::memory::{AppCalib, Link};
use ops_oc::ops::OpsContext;
use ops_oc::runtime::{default_artifacts_dir, Runtime};

// Tall grid: the 63-loop chain skews by ~50 planes, so tiles need room
// along y (matches the aot.py --cl-nx/--cl-ny artifact shape).
const NX: usize = 16;
const NY: usize = 1024;
const STEPS: usize = 20;

fn build_ctx(platform: Platform, pjrt: bool) -> (OpsContext, CloverLeaf2D, usize) {
    let cfg = Config::new(platform, AppCalib::CLOVERLEAF_2D);
    let mut ctx = OpsContext::new(cfg.build_engine());
    // model ~48 GB: 3x oversubscription of the 16 GB fast memory
    let base = {
        let mut probe = OpsContext::new(Config::new(platform, AppCalib::CLOVERLEAF_2D).build_engine());
        CloverLeaf2D::new(&mut probe, NX, NY, 1);
        probe.problem_bytes()
    };
    let scale = (48.0e9 / base as f64).round() as u64;
    let app = CloverLeaf2D::new(&mut ctx, NX, NY, scale);

    let mut bound = 0;
    if pjrt {
        match Runtime::cpu()
            .and_then(|rt| rt.load_manifest(&default_artifacts_dir().join("manifest.txt")))
        {
            Ok(arts) => {
                let mut exec = PjrtExecutor::new();
                for (_k, (spec, art)) in arts {
                    if spec.kernel == "cl2d_ideal_gas" {
                        exec.register(&spec, art, ctx.datasets()).expect("register");
                        bound += 1;
                    }
                }
                ctx.set_executor(Box::new(exec));
            }
            Err(e) => eprintln!("WARN: no PJRT artifacts ({e}); running native only"),
        }
    }
    (ctx, app, bound)
}

fn main() {
    println!("=== ops-oc end-to-end: CloverLeaf 2D at 3x oversubscription ===\n");

    // Reference: untiled flat run, native executor.
    let (mut ref_ctx, mut ref_app, _) = build_ctx(Platform::KnlFlatDdr4, false);
    ref_app.run(&mut ref_ctx, STEPS, 10);
    let ref_density = ref_ctx.fetch(ref_app.density0);
    let ref_summary = ref_app.field_summary(&mut ref_ctx);

    // The out-of-core run: explicit 3-slot streaming over NVLink, with
    // the ideal-gas kernel dispatched to the XLA artifact.
    let platform = Platform::GpuExplicit {
        link: Link::NvLink,
        cyclic: true,
        prefetch: true,
    };
    let (mut ctx, mut app, bound) = build_ctx(platform, true);
    println!(
        "PJRT kernels bound: {bound} (cl2d_ideal_gas via JAX/Pallas artifact)"
    );
    app.run(&mut ctx, STEPS, 10);
    let summary = app.field_summary(&mut ctx);
    let density = ctx.fetch(app.density0);

    println!("\nfield_summary after {STEPS} steps:");
    println!("  volume          {:>14.6}", summary.volume);
    println!("  mass            {:>14.6}", summary.mass);
    println!("  internal energy {:>14.6}", summary.internal_energy);
    println!("  kinetic energy  {:>14.8}", summary.kinetic_energy);
    println!("  pressure        {:>14.6}", summary.pressure);

    // Cross-backend checks. The XLA-compiled EOS differs from the native
    // kernel by ~1 ulp (instruction ordering); shock hydrodynamics with
    // branchy flux limiters amplifies that chaotically, so the long-run
    // comparison uses *integral* quantities, and the per-cell comparison
    // a short horizon (the bit-exact claims — tiled == untiled on the
    // same executor — live in rust/tests/tiling_equivalence.rs).
    let mass_drift = ((summary.mass - ref_summary.mass) / ref_summary.mass).abs();
    assert!(mass_drift < 1e-6, "mass drift {mass_drift}");
    let e_ref = ref_summary.internal_energy + ref_summary.kinetic_energy;
    let e_got = summary.internal_energy + summary.kinetic_energy;
    let e_drift = ((e_got - e_ref) / e_ref).abs();
    println!("\ntotal-energy drift vs reference: {e_drift:.3e}");
    // the predictor-corrector scheme is dissipative, not exactly
    // energy-conserving: diverged trajectories legitimately differ at the
    // per-mille level after 20 steps
    assert!(e_drift < 1e-2, "energy drift {e_drift}");
    let max_diff = ref_density
        .iter()
        .zip(&density)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("max |density - reference| after {STEPS} steps = {max_diff:.3e} (ulp-seeded, limiter-amplified)");

    // Kernel-level equivalence (the definitive L1/L2 check — no branchy
    // limiters in the way): one ideal_gas application, PJRT vs native,
    // on identical inputs.
    let (mut kref_ctx, kref_app, _) = build_ctx(Platform::KnlFlatDdr4, false);
    kref_app.initialise(&mut kref_ctx);
    let p_native = kref_ctx.fetch(kref_app.pressure);
    let (mut kctx2, kapp2, kb) = build_ctx(Platform::KnlFlatDdr4, true);
    kapp2.initialise(&mut kctx2);
    let p_pjrt = kctx2.fetch(kapp2.pressure);
    assert_eq!(kb, 1);
    let kdiff = p_native
        .iter()
        .zip(&p_pjrt)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("max |pressure: PJRT - native| (one EOS application) = {kdiff:.3e}");
    assert!(kdiff < 1e-12, "kernel-level divergence {kdiff}");

    println!();
    print_summary(
        &platform.label(),
        ctx.problem_bytes(),
        ctx.metrics(),
        ctx.oom(),
    );

    // headline: the same problem on the KNL cache-mode path
    let (mut kctx, mut kapp, _) = build_ctx(Platform::KnlCacheTiled, false);
    kapp.run(&mut kctx, STEPS, 10);
    println!();
    print_summary(
        "KNL cache tiled",
        kctx.problem_bytes(),
        kctx.metrics(),
        kctx.oom(),
    );
    println!("\nE2E OK: all layers compose; conservation + cross-backend checks pass.");
}
