//! Quickstart: declare a stencil problem in the DSL, run it through two
//! modelled memory systems, and print the paper's Average Bandwidth
//! metric.
//!
//!     cargo run --release --example quickstart

use ops_oc::apps::diffusion::Diffusion2D;
use ops_oc::coordinator::{print_summary, Config, Platform};
use ops_oc::memory::{AppCalib, Link};
use ops_oc::ops::OpsContext;

fn main() {
    // A 2D diffusion problem whose modelled size (scale x actual bytes)
    // is ~24 GB — 1.5x larger than the 16 GB fast memory.
    let scale = 1 << 15;

    for platform in [
        Platform::KnlCacheTiled,
        Platform::GpuExplicit {
            link: Link::NvLink,
            cyclic: true,
            prefetch: true,
        },
    ] {
        let cfg = Config::new(platform, AppCalib::CLOVERLEAF_2D);
        let mut ctx = OpsContext::new(cfg.build_engine());
        let app = Diffusion2D::new(&mut ctx, 16, 3072, scale);
        app.run(&mut ctx, 50, 5);
        let heat = {
            // a trigger point: returns data, flushes the chain
            let mut c2 = ctx;
            let h = app.total_heat(&mut c2);
            ctx = c2;
            h
        };
        println!("final interior heat: {heat:.6}");
        print_summary(
            &platform.label(),
            ctx.problem_bytes(),
            ctx.metrics(),
            ctx.oom(),
        );
        println!();
    }
}
