//! OpenSBLI Taylor–Green vortex: tiling across multiple timesteps (the
//! paper's §5.3 depth study — "we can tile across an arbitrary number of
//! loops"), plus the physics monitor.
//!
//!     cargo run --release --example opensbli_tgv

use ops_oc::apps::opensbli::OpenSbli;
use ops_oc::coordinator::{print_summary, Config, Platform};
use ops_oc::memory::{AppCalib, Link};
use ops_oc::ops::OpsContext;

fn main() {
    println!("=== OpenSBLI 3D Taylor-Green vortex ===\n");

    // physics run: watch the kinetic energy decay
    let cfg = Config::new(Platform::KnlFlatDdr4, AppCalib::OPENSBLI);
    let mut ctx = OpsContext::new(cfg.build_engine());
    let mut app = OpenSbli::new(&mut ctx, 32, 1, 1);
    app.initialise(&mut ctx);
    ctx.flush();
    println!("kinetic-energy decay (Re=1600, 32^3):");
    for step in 0..6 {
        app.exchange_halos(&mut ctx);
        app.step(&mut ctx, 0);
        let ke = app.kinetic_energy(&mut ctx);
        println!("  step {:>2}  KE = {ke:.6}", step + 1);
    }

    // tile-depth study at 47 GB modelled, PCIe vs NVLink
    println!("\ntiling depth study at 47 GB (cf. paper §5.3 / Fig. 10):");
    for link in [Link::PciE, Link::NvLink] {
        for spc in [1usize, 2, 3] {
            let (m, _) = ops_oc::bench_support::run_sbli_tall(
                Platform::GpuExplicit {
                    link,
                    cyclic: true,
                    prefetch: true,
                },
                spc,
                47.0,
                2,
            );
            println!(
                "  {} tile over {spc} timestep(s): {:>6.1} GB/s effective",
                link.name(),
                m.effective_bandwidth_gbs()
            );
        }
    }

    let (m, oom) = ops_oc::bench_support::run_sbli_tall(Platform::KnlCacheTiled, 3, 47.0, 2);
    println!();
    print_summary("KNL cache tiled, 3 steps/chain", 47_000_000_000, &m, oom);
}
