//! Unified-memory study (§5.4): page-fault-driven migration collapses
//! once the problem exceeds device memory; tiling recovers some of it;
//! bulk prefetches more — but explicit management stays ahead.
//!
//!     cargo run --release --example unified_memory

use ops_oc::bench_support::{run_cl2d, Figure};
use ops_oc::coordinator::Platform;
use ops_oc::memory::Link;

fn main() {
    println!("=== CloverLeaf 2D with Unified Memory (cf. paper Fig. 11) ===\n");
    let mut fig = Figure::new("Unified memory problem scaling", "effective GB/s (modelled)");
    let configs: [(&str, Box<dyn Fn(f64) -> Option<f64>>); 4] = [
        (
            "UM no tiling",
            Box::new(|gb| {
                let (m, o) = run_cl2d(
                    Platform::GpuUnified { link: Link::PciE, tiled: false, prefetch: false },
                    8, 6144, gb, 8, 0,
                );
                (!o).then(|| m.effective_bandwidth_gbs())
            }),
        ),
        (
            "UM tiling",
            Box::new(|gb| {
                let (m, o) = run_cl2d(
                    Platform::GpuUnified { link: Link::PciE, tiled: true, prefetch: false },
                    8, 6144, gb, 8, 0,
                );
                (!o).then(|| m.effective_bandwidth_gbs())
            }),
        ),
        (
            "UM tiling+prefetch",
            Box::new(|gb| {
                let (m, o) = run_cl2d(
                    Platform::GpuUnified { link: Link::PciE, tiled: true, prefetch: true },
                    8, 6144, gb, 8, 0,
                );
                (!o).then(|| m.effective_bandwidth_gbs())
            }),
        ),
        (
            "explicit (for reference)",
            Box::new(|gb| {
                let (m, o) = run_cl2d(
                    Platform::GpuExplicit { link: Link::PciE, cyclic: true, prefetch: true },
                    8, 6144, gb, 8, 0,
                );
                (!o).then(|| m.effective_bandwidth_gbs())
            }),
        ),
    ];

    let mut handles = vec![];
    for (name, _) in &configs {
        handles.push(fig.add_series(name));
    }
    for gb in [8.0, 13.0, 16.0, 24.0, 36.0, 47.0] {
        for (i, (_, f)) in configs.iter().enumerate() {
            fig.push(handles[i], gb, f(gb));
        }
    }
    println!("{}", fig.render());

    let (m, _) = run_cl2d(
        Platform::GpuUnified { link: Link::PciE, tiled: false, prefetch: false },
        8, 6144, 36.0, 8, 0,
    );
    println!(
        "page faults at 36 GB untiled: {} ({:.1} GB migrated)",
        m.page_faults,
        m.h2d_bytes as f64 / 1e9
    );
}
