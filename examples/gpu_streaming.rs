//! The paper's Algorithm 1 in action: explicit 3-slot streaming of
//! CloverLeaf 2D over PCIe vs NVLink, with the §4.1 optimisations
//! toggled one at a time — a miniature of Figures 7–8.
//!
//!     cargo run --release --example gpu_streaming

use ops_oc::bench_support::{run_cl2d, Figure};
use ops_oc::coordinator::Platform;
use ops_oc::memory::Link;

fn main() {
    println!("=== CloverLeaf 2D, explicit GPU memory management ===\n");

    let mut fig = Figure::new(
        "Tiling optimisations (cf. paper Fig. 8)",
        "effective GB/s (modelled)",
    );
    let combos = [
        ("NoPrefetch NoCyclic", false, false),
        ("NoPrefetch Cyclic", true, false),
        ("Prefetch Cyclic", true, true),
    ];
    for link in [Link::PciE, Link::NvLink] {
        for (name, cyclic, prefetch) in combos {
            let s = fig.add_series(&format!("{}-{}", link.name(), name));
            for gb in [8.0, 16.0, 32.0, 47.0] {
                let (m, oom) = run_cl2d(
                    Platform::GpuExplicit {
                        link,
                        cyclic,
                        prefetch,
                    },
                    8,
                    6144,
                    gb,
                    4,
                    0,
                );
                fig.push(
                    s,
                    gb,
                    if oom {
                        None
                    } else {
                        Some(m.effective_bandwidth_gbs())
                    },
                );
            }
        }
    }
    println!("{}", fig.render());

    // transfer ledger for one configuration
    let (m, _) = run_cl2d(
        Platform::GpuExplicit {
            link: Link::PciE,
            cyclic: true,
            prefetch: true,
        },
        8,
        6144,
        47.0,
        4,
        0,
    );
    println!("transfer ledger at 47 GB (PCIe, Cyclic+Prefetch):");
    println!("  H2D {:>8.1} GB", m.h2d_bytes as f64 / 1e9);
    println!("  D2H {:>8.1} GB", m.d2h_bytes as f64 / 1e9);
    println!("  D2D {:>8.1} GB (tile edge copies)", m.d2d_bytes as f64 / 1e9);
    println!("  tiles executed: {}", m.tiles);
}
